"""Sharding rules + shape specs (single-device: rules only, no mesh
construction beyond 1-device meshes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, all_cells, get_config
from repro.configs.shapes import SHAPES, batch_specs, shape_applicable


class FakeMesh:
    """Duck-typed mesh for rule testing without devices."""
    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


from repro.launch.mesh import batch_pspec, cache_pspec, param_pspec


SP = FakeMesh({"data": 16, "model": 16})
MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_rules_embed():
    # vocab divisible by 16 -> sharded on model
    assert param_pspec("embed", (64000, 7168), SP) == P("model", ("data",))
    # mamba vocab 50280 NOT divisible -> falls to d_model on model
    spec = param_pspec("embed", (50280, 1536), SP)
    assert spec == P(None, "model")


def test_param_rules_proj():
    assert param_pspec("blocks/mixer_0/wq", (60, 7168, 7168), SP) \
        == P(None, ("data",), "model")
    assert param_pspec("blocks/mixer_0/wo", (60, 7168, 7168), SP) \
        == P(None, "model", ("data",))


def test_param_rules_experts():
    spec = param_pspec("blocks/ffn_0/wi", (60, 160, 5120, 1536), SP)
    assert spec[1] == "model"      # EP on expert dim


def test_param_rules_non_divisible_drops():
    spec = param_pspec("blocks/mixer_0/wq", (2, 100, 37), SP)
    assert spec == P(None, None, None)


def test_param_rules_multipod_fsdp():
    spec = param_pspec("blocks/ffn_0/wi", (60, 5120, 20480), MP)
    assert spec == P(None, ("pod", "data"), "model")


def test_batch_pspec():
    assert batch_pspec("tokens", (256, 4096), SP) == P(("data",), None)
    assert batch_pspec("tokens", (16, 16, 4096), SP, microbatched=True) \
        == P(None, ("data",), None)
    # batch=1 (long_500k): cannot shard
    assert batch_pspec("token", (1,), SP) == P(None)


def test_cache_pspec_decode():
    # dense KV (G,B,T,Hkv,D): batch on data; kv heads 8 !| 16 -> seq
    spec = cache_pspec("mixer_0/k", (60, 128, 32768, 8, 128), SP, False)
    assert spec == P(None, ("data",), "model", None, None)
    # long-context: sequence over (data, model)
    spec = cache_pspec("mixer_4/k", (4, 1, 524288, 8, 128), SP, True)
    assert spec == P(None, None, ("data", "model"), None, None)
    # mamba state: heads on model
    spec = cache_pspec("mixer_0/state", (48, 128, 48, 64, 128), SP, False)
    assert spec == P(None, ("data",), "model", None, None)


def test_all_cells_and_skips():
    cells, skips = all_cells()
    assert len(cells) + len(skips) == 40
    assert len(skips) == 8            # 8 full-attention archs skip long_500k
    skip_archs = {a for a, s, _ in skips}
    assert "mamba2-780m" not in skip_archs
    assert "jamba-v0.1-52b" not in skip_archs


@pytest.mark.parametrize("arch", list(ARCHS))
def test_batch_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for sname, spec in SHAPES.items():
        if not shape_applicable(cfg, spec):
            continue
        specs = batch_specs(cfg, spec)
        assert specs, (arch, sname)
        for k, v in specs.items():
            assert all(d > 0 for d in v.shape)


def test_exact_assigned_configs():
    """Spot-check the exact assigned numbers (guard against drift)."""
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) \
        == (60, 5120, 128, 102400)
    assert (c.n_experts, c.top_k, c.kv_lora) == (160, 6, 512)
    c = get_config("nemotron-4-15b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab, c.act) \
        == (32, 6144, 24576, 256000, "relu2")
    c = get_config("yi-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) \
        == (60, 7168, 56, 8, 20480)
    c = get_config("whisper-large-v3")
    assert (c.n_enc_layers, c.n_layers, c.d_model, c.vocab) \
        == (32, 32, 1280, 51866)
    c = get_config("jamba-v0.1-52b")
    assert (c.attn_period, c.n_experts, c.top_k) == (8, 16, 2)
    c = get_config("mamba2-780m")
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
