"""Per-arch smoke tests: REDUCED config of each family, one forward /
train step on CPU asserting output shapes + no NaNs; decode-vs-forward
consistency; ResNet family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.registry import model_fns
from repro.models import resnet
from repro.approx.backend import MatmulBackend
from repro.approx.layers import ApproxPolicy
from repro.core.luts import exact_mul_lut


def _batch_for(cfg, b, s):
    batch = {"tokens": jnp.full((b, s), 3, jnp.int32),
             "targets": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.full((b, cfg.n_img_tokens, cfg.d_model),
                                       0.1, jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.full((b, cfg.enc_frames, cfg.d_model), 0.1,
                                   jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", list(ARCHS))
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, 2, 32)
    loss, grads = jax.value_and_grad(
        lambda p: fns.forward_train(p, batch, cfg))(params)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-780m",
                                  "jamba-v0.1-52b", "whisper-large-v3"])
def test_prefill_decode_consistency(arch):
    """Greedy next-token from (prefill S) + (decode 1) must equal the
    prediction from prefilling S+1 tokens directly."""
    cfg = get_config(arch).reduced()
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0,
                              cfg.vocab)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jnp.full((b, cfg.enc_frames, cfg.d_model), 0.1,
                                    jnp.float32)

    cache = fns.init_cache(cfg, b, s + 2)
    logits_a, cache = fns.forward_prefill(
        params, {"tokens": toks[:, :s], **extras}, cache, cfg)
    logits_b, _ = fns.forward_decode(params, toks[:, s], cache, cfg)

    cache2 = fns.init_cache(cfg, b, s + 2)
    logits_full, _ = fns.forward_prefill(
        params, {"tokens": toks[:, :s + 1], **extras}, cache2, cfg)
    np.testing.assert_allclose(np.asarray(logits_b),
                               np.asarray(logits_full), rtol=2e-2,
                               atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b"])
def test_moe_routing_mass(arch):
    """Top-k routing weights are normalized; output magnitude sane."""
    cfg = get_config(arch).reduced()
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, 2, 16)
    loss = fns.forward_train(params, batch, cfg)
    assert jnp.isfinite(loss) and float(loss) < 20.0


def test_resnet_forward_and_counts():
    cfg = resnet.resnet_config(8)
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).uniform(
        size=(4, 32, 32, 3)).astype(np.float32))
    logits = resnet.forward(params, x, cfg)
    assert logits.shape == (4, 10)
    assert jnp.isfinite(logits).all()
    counts = resnet.layer_mult_counts(cfg)
    assert len(counts) == 9  # conv_init + 6 block convs + 2 projections
    # stage-3 conv2 has the largest share at equal block counts? the
    # paper's point: later-stage convs dominate multiplier counts
    total = sum(counts.values())
    assert counts["s2_b0_conv2"] / total > 0.15


def test_resnet_depths():
    for depth in (8, 14, 20):
        cfg = resnet.resnet_config(depth)
        assert cfg.depth == depth


@pytest.mark.slow
def test_resnet_approx_policy_changes_output():
    """A very aggressive approximate multiplier must change logits; the
    exact-LUT multiplier must not (vs int8)."""
    cfg = resnet.resnet_config(8)
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).uniform(
        size=(2, 32, 32, 3)).astype(np.float32))
    int8 = ApproxPolicy(default=MatmulBackend(mode="int8"))
    lut_exact = ApproxPolicy(default=MatmulBackend(mode="lut",
                                                   lut=exact_mul_lut(8)))
    la = resnet.forward(params, x, cfg, int8)
    lb = resnet.forward(params, x, cfg, lut_exact)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-5,
                               atol=1e-5)
    # truncate 4 LSBs of both operands: strong approximation
    from repro.core.families import truncated_multiplier
    from repro.core.luts import lut_from_netlist
    lut_t = lut_from_netlist(truncated_multiplier(8, 4), 8)
    approx = ApproxPolicy(default=MatmulBackend(mode="lut", lut=lut_t))
    lc = resnet.forward(params, x, cfg, approx)
    assert float(jnp.abs(lc - la).max()) > 1e-3
