"""Module-axis approximation tests (DESIGN.md §2.12): taxonomy
coverage, lowering onto the per-layer PolicyBank axis, bit-identity of
module-keyed banked sweeps vs per-layer lowering and vs sequential
evaluation, and the O(1) trace-count gate on MoE + SSM models."""
import jax
import jax.numpy as jnp
import pytest

from repro.approx.dse import verify_assignments
from repro.approx.modules import (EXACT_FAMILIES, FILL_EXACT,
                                  MODULE_FAMILIES, ModuleMap, module_of,
                                  module_policy_bank,
                                  module_sweep_assignments)
from repro.approx.specs import PolicyBank
from repro.approx.workload import layer_mult_counts, lm_fidelity
from repro.core.families import truncated_multiplier
from repro.core.library import ApproxLibrary
from repro.core.seeds import array_multiplier
from repro.launch.compile_cache import trace_audit

MULTS = ["mul8u_exact", "mul8u_trunc6", "mul8u_trunc3"]


@pytest.fixture(scope="module")
def lib():
    lib = ApproxLibrary()
    exact = array_multiplier(8)
    lib.add_netlist(exact, "multiplier", 8, "exact", exact,
                    name="mul8u_exact")
    for k in (2, 5):
        lib.add_netlist(truncated_multiplier(8, k), "multiplier", 8,
                        "truncation", exact)
    return lib


# ----------------------------------------------------------------------
# Taxonomy / classifier
# ----------------------------------------------------------------------
def test_module_of_covers_representative_tags():
    assert module_of("attn.wq") == "attention.q"
    assert module_of("enc.attn.wk") == "attention.k"
    assert module_of("dec.attn.wo") == "attention.o"
    assert module_of("mla.wdq") == "attention.q"
    assert module_of("mla.wuk") == "attention.k"
    assert module_of("mla.wkr") == "attention.k"
    assert module_of("mla.wuv") == "attention.v"
    assert module_of("mla.wo") == "attention.o"
    assert module_of("ffn.wi") == "mlp.up"
    assert module_of("ffn.wg") == "mlp.gate"
    assert module_of("moe.shared.wo") == "mlp.down"
    assert module_of("moe.wi") == "moe.expert"
    assert module_of("moe.wg") == "moe.expert"
    assert module_of("mamba.in_proj") == "ssm.in_proj"
    assert module_of("mamba.out_proj") == "ssm.out_proj"
    assert module_of("xattn.wq") == "cross_attention"
    assert module_of("img_proj") == "embed"
    assert module_of("conv_init") == "conv"
    assert module_of("s1_b0_proj") == "conv"
    assert module_of("s0_b1_conv2") == "conv"
    assert module_of("head") == "head"


def test_module_of_rejects_unknown_tags():
    with pytest.raises(ValueError, match="unknown layer tag"):
        module_of("mystery.w")


def test_classifier_lands_in_registered_families():
    tags = ["attn.wq", "mla.wdkv", "ffn.wo", "moe.wi", "moe.shared.wi",
            "mamba.in_proj", "xattn.wv", "img_proj", "conv_init", "head"]
    for t in tags:
        fam = module_of(t)
        assert fam in MODULE_FAMILIES
        assert fam not in EXACT_FAMILIES


@pytest.mark.parametrize("arch", [
    "qwen1.5-0.5b", "qwen3-moe-30b-a3b", "deepseek-v2-236b",
    "mamba2-780m", "jamba-v0.1-52b", "whisper-large-v3",
    "llava-next-34b", "nemotron-4-15b"])
def test_counts_match_probed_call_sites(arch):
    """The MAC-accounting drift guard: for every zoo family, the
    counted tags are EXACTLY the call sites one abstract prefill hits,
    and every tag classifies."""
    from repro.configs import get_config
    from repro.models.registry import model_fns, probe_layer_tags

    cfg = get_config(arch).reduced()
    fns = model_fns(cfg)
    params = jax.eval_shape(lambda k: fns.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    tags = set(probe_layer_tags(cfg, params))
    counts = layer_mult_counts(cfg, batch=2, seq_len=8)
    assert set(counts) == tags
    mmap = ModuleMap.for_config(cfg, batch=2, seq_len=8, validate=False)
    assert set(mmap.layer_module.values()) <= set(MODULE_FAMILIES)


# ----------------------------------------------------------------------
# ModuleMap / lowering
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def moe_map():
    from repro.configs import get_config
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    return cfg, ModuleMap.for_config(cfg, batch=2, seq_len=8)


def test_module_map_lowering_and_counts(moe_map):
    _cfg, mmap = moe_map
    assert "moe.expert" in mmap.modules
    lowered = mmap.lower({"moe.expert": "mul8u_trunc3",
                          "attention.q": "mul8u_trunc6"})
    assert lowered["moe.wi"] == "mul8u_trunc3"
    assert lowered["moe.wo"] == "mul8u_trunc3"
    assert lowered["attn.wq"] == "mul8u_trunc6"
    assert "attn.wk" not in lowered
    mc = mmap.module_counts()
    assert sum(mc.values()) == sum(mmap.layer_counts.values())
    assert mc["moe.expert"] == sum(
        mmap.layer_counts[l] for l in mmap.module_layers("moe.expert"))
    shares = mmap.module_shares()
    assert sum(shares.values()) == pytest.approx(1.0)


def test_lowering_rejects_exact_and_absent_families(moe_map):
    _cfg, mmap = moe_map
    with pytest.raises(ValueError, match="exact by design"):
        mmap.lower({"moe.router": "mul8u_trunc3"})
    with pytest.raises(ValueError, match="no call sites"):
        mmap.lower({"conv": "mul8u_trunc3"})


def test_module_policy_bank_fill_pads_partial_rows(moe_map, lib):
    _cfg, mmap = moe_map
    pbank, lowered = module_policy_bank(
        mmap, [{"moe.expert": "mul8u_trunc3"}], lib)
    assert pbank.layers == mmap.layers
    a = pbank.assignment(0)
    for l in mmap.module_layers("moe.expert"):
        assert a[l] == "mul8u_trunc3"
    for l in set(mmap.layers) - set(mmap.module_layers("moe.expert")):
        assert a[l] == FILL_EXACT
    assert lowered[0] == mmap.lower({"moe.expert": "mul8u_trunc3"})


def test_from_assignments_without_fill_still_rejects_partial(lib):
    with pytest.raises(ValueError, match="misses layers"):
        PolicyBank.from_assignments(
            [{"a": "mul8u_exact"}], lib, layers=("a", "b"))


# ----------------------------------------------------------------------
# Bit-identity + O(1) banked programs (satellite: MoE and mamba2)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "mamba2-780m"])
def test_module_sweep_bit_identity_and_single_program(arch, lib):
    """A mixed-module banked sweep is (a) bit-identical to the same
    assignments evaluated sequentially, (b) bit-identical to the
    equivalent hand-built per-layer assignment rows, and (c) ONE traced
    program regardless of the number of module rows."""
    from repro.configs import get_config

    cfg = get_config(arch).reduced()
    wl = lm_fidelity(cfg, batch=2, seq_len=8, n_batches=1)
    mmap = ModuleMap.for_config(cfg, batch=2, seq_len=8)
    grid = module_sweep_assignments(mmap, MULTS[1:])
    lowered = [mmap.lower(a) for _f, _m, a in grid]

    with trace_audit() as tc_full:
        banked = verify_assignments(
            wl, lowered, mmap.layer_counts, lib,
            layers=mmap.layers, fill=FILL_EXACT)
    sequential = verify_assignments(
        wl, lowered, mmap.layer_counts, lib, batch=False,
        layers=mmap.layers, fill=FILL_EXACT)
    # (a) banked == sequential, bit for bit
    for b, s in zip(banked, sequential):
        assert b.metrics == s.metrics
        assert b.network_rel_power == s.network_rel_power

    # (b) module lowering == explicit per-layer PolicyBank assignment
    explicit = [{l: a.get(l, FILL_EXACT) for l in mmap.layers}
                for a in lowered]
    per_layer = verify_assignments(wl, explicit, mmap.layer_counts, lib)
    for b, p in zip(banked, per_layer):
        assert b.metrics == p.metrics

    # (c) O(1) compiled programs: fewer rows -> same trace count
    with trace_audit() as tc_half:
        verify_assignments(wl, lowered[:2], mmap.layer_counts, lib,
                           layers=mmap.layers, fill=FILL_EXACT)
    assert tc_full.traced_programs == tc_half.traced_programs == 1


def test_fill_lane_matches_golden_base(lib):
    """The exact-LUT fill is bit-identical to the golden int8 base the
    sequential policies default to — the property that makes partial
    module rows safe inside one bank."""
    from repro.approx.layers import ApproxPolicy
    from repro.approx.specs import BackendSpec

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    golden = ApproxPolicy(default=BackendSpec.golden().materialize())
    filled = ApproxPolicy(default=BackendSpec.golden().materialize(),
                          overrides=[("m", BackendSpec(
                              mode="lut", multiplier=FILL_EXACT
                          ).materialize(lib))])
    assert bool(jnp.all(golden.matmul("m", x, w)
                        == filled.matmul("m", x, w)))
