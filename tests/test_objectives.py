"""Objective registry + N-d Pareto + declarative select (DESIGN.md
§2.7): the 2-d default must be bit-identical to the pre-§2.7 sweep,
N-d fronts must be non-dominated and axis-order invariant, and
``select`` must reproduce ``select_multiplier`` declaratively."""
import itertools
import json

import numpy as np
import pytest

from repro.approx.dse import (DesignPoint, ExploreResult, pareto_points,
                              select_multiplier)
from repro.approx.objectives import (AtLeast, AtMost, MaxDrop, Objective,
                                     UnknownObjectiveError,
                                     available_objectives,
                                     ensure_objective, get_objective,
                                     select, value_of)
from repro.approx.objectives import pareto_points as pareto_nd

RNG = np.random.default_rng(42)


def _legacy_pareto_2d(points):
    """The pre-§2.7 (accuracy max, power min) sweep, verbatim — the
    bit-identity reference for the generic N-d implementation."""
    pts = sorted(points, key=lambda p: (p.network_rel_power, -p.accuracy))
    front, best_acc, i = [], float("-inf"), 0
    while i < len(pts):
        j = i
        power = pts[i].network_rel_power
        while j < len(pts) and pts[j].network_rel_power == power:
            j += 1
        acc_max = pts[i].accuracy
        if acc_max > best_acc:
            front.extend(p for p in pts[i:j] if p.accuracy == acc_max)
            best_acc = acc_max
        i = j
    return front


def _random_points(n, seed, with_axes=False, ties=True):
    rng = np.random.default_rng(seed)
    pts = []
    for k in range(n):
        # quantized values so exact ties (the old sweep's subtlest
        # branch) actually occur
        acc = round(float(rng.integers(0, 8)) / 8.0, 6) if ties \
            else float(rng.random())
        power = round(float(rng.integers(1, 8)) / 8.0, 6) if ties \
            else float(rng.random())
        costs = ({"area": float(rng.integers(1, 5)) / 4.0,
                  "delay": float(rng.integers(1, 5)) / 4.0}
                 if with_axes else {})
        pts.append(DesignPoint(f"m{k}", "all", acc, power, power, 1.0,
                               costs=costs))
    return pts


def test_2d_default_bit_identical_to_legacy_sweep():
    for seed in range(20):
        pts = _random_points(24, seed)
        new = pareto_points(pts)
        old = _legacy_pareto_2d(pts)
        # identical membership AND order, comparing object identity
        assert [id(p) for p in new] == [id(p) for p in old], \
            f"divergence at seed {seed}"


def test_2d_known_front_and_ties():
    pts = [DesignPoint("a", "all", 0.9, 1.0, 1.0, 1.0),
           DesignPoint("b", "all", 0.8, 0.5, 0.5, 1.0),
           DesignPoint("b2", "all", 0.8, 0.5, 0.5, 1.0),  # exact tie
           DesignPoint("c", "all", 0.7, 0.6, 0.6, 1.0),   # dominated
           DesignPoint("d", "all", 0.5, 0.2, 0.2, 1.0)]
    assert [p.multiplier for p in pareto_points(pts)] \
        == ["d", "b", "b2", "a"]


def _dominates(vals_q, vals_p):
    return all(a <= b for a, b in zip(vals_q, vals_p)) and \
        any(a < b for a, b in zip(vals_q, vals_p))


@pytest.mark.parametrize("axes", [("accuracy", "power"),
                                  ("accuracy", "power", "delay"),
                                  ("accuracy", "power", "area", "delay")])
def test_nd_front_nondominated_invariant(axes):
    """Every front member is non-dominated; every excluded point is
    dominated by some front member."""
    for seed in range(5):
        pts = _random_points(20, seed, with_axes=True)
        front = pareto_nd(pts, axes)
        signs = [get_objective(a).sign for a in axes]

        def sv(p):
            return tuple(s * value_of(p, a) for s, a in zip(signs, axes))
        front_ids = {id(p) for p in front}
        for p in pts:
            dominated = any(_dominates(sv(q), sv(p)) for q in pts
                            if q is not p)
            assert (id(p) in front_ids) == (not dominated)


def test_nd_front_invariant_under_axis_permutation():
    for seed in range(5):
        pts = _random_points(18, seed, with_axes=True)
        base = {id(p) for p in pareto_nd(pts, ("accuracy", "power",
                                               "delay"))}
        for perm in itertools.permutations(("accuracy", "power",
                                            "delay")):
            assert {id(p) for p in pareto_nd(pts, perm)} == base, perm


def test_extra_axis_resolves_ties_only():
    """Adding an axis can only change front membership through points
    that TIE on every original axis (the extra axis then breaks the
    tie); any point strictly inside the 2-d front stays excluded."""
    pts = _random_points(30, 7, with_axes=True)
    f2 = {id(p) for p in pareto_nd(pts, ("accuracy", "power"))}
    f3 = {id(p) for p in pareto_nd(pts, ("accuracy", "power", "delay"))}
    for p in pts:
        if id(p) in f3 - f2:
            # newly admitted: must tie some 2-d front point exactly
            assert any(q.accuracy == p.accuracy
                       and q.network_rel_power == p.network_rel_power
                       for q in pts if id(q) in f2)
        if id(p) in f2 - f3:
            # newly excluded: only a tie broken by delay can do that
            assert any(q.accuracy == p.accuracy
                       and q.network_rel_power == p.network_rel_power
                       and q.costs["delay"] < p.costs["delay"]
                       for q in pts)


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------
def test_unknown_objective_error_is_actionable():
    with pytest.raises(UnknownObjectiveError) as e:
        get_objective("no_such_axis")
    assert "no_such_axis" in str(e.value)
    assert "power" in str(e.value)            # lists known axes


def test_ensure_objective_idempotent_and_conflict():
    a = ensure_objective("test_only_axis", "min")
    assert ensure_objective("test_only_axis", "min") is a
    with pytest.raises(ValueError):
        ensure_objective("test_only_axis", "max")
    assert "test_only_axis" in available_objectives()


def test_builtin_axes_directions():
    assert get_objective("accuracy").direction == "max"
    for axis in ("power", "area", "delay", "er", "mae", "wce"):
        assert get_objective(axis).direction == "min"


def test_value_of_prefers_measured_metrics_over_getters():
    p = DesignPoint("m", "all", 0.9, 0.4, 0.4, 1.0,
                    metrics={"accuracy": 0.8, "mae": 123.0},
                    errors={"mae": 7.0})
    assert value_of(p, "accuracy") == 0.8     # measured wins over alias
    assert value_of(p, "mae") == 123.0        # ... and over errors dict
    assert value_of(p, "power") == 0.4


def test_accuracy_axis_refuses_to_alias_foreign_primary():
    """A point measured by a non-classification workload must not
    resolve the 'accuracy' axis off its scalar alias column (which
    holds a min-direction primary like logit MAE) — the legacy default
    front would silently keep the WORST-fidelity design."""
    good = DesignPoint("good", "all", 0.01, 0.5, 0.5, 1.0,
                       metrics={"logit_mae": 0.01})
    bad = DesignPoint("bad", "all", 5.0, 0.5, 0.5, 1.0,
                      metrics={"logit_mae": 5.0})
    with pytest.raises(KeyError, match="logit_mae"):
        value_of(good, "accuracy")
    with pytest.raises(KeyError):
        pareto_points([good, bad])        # legacy default objectives
    # pre-§2.7 points (no metrics dict) keep the scalar fallback
    legacy = DesignPoint("m", "all", 0.9, 0.5, 0.5, 1.0)
    assert value_of(legacy, "accuracy") == 0.9


def test_value_of_missing_axis_raises_with_context():
    p = DesignPoint("m", "hetero", 0.9, 0.4, 0.4, 1.0)
    with pytest.raises(KeyError):
        value_of(p, "delay")
    with pytest.raises(KeyError):
        value_of(p, "wce")


# ----------------------------------------------------------------------
# Declarative select
# ----------------------------------------------------------------------
def _result():
    pts = [DesignPoint("exact", "all", 0.90, 1.00, 1.00, 1.0,
                       costs={"area": 1.0, "delay": 1.0}),
           DesignPoint("cheap", "all", 0.89, 0.50, 0.50, 1.0,
                       costs={"area": 0.6, "delay": 1.2}),
           DesignPoint("cheapest", "all", 0.70, 0.20, 0.20, 1.0,
                       costs={"area": 0.3, "delay": 0.9})]
    return ExploreResult(baseline_accuracy=0.90, all_layers=pts,
                         baseline_metrics={"accuracy": 0.90})


def test_select_reproduces_select_multiplier():
    result = _result()
    for drop in (0.0, 0.02, 0.5):
        legacy = select_multiplier(result, drop)
        new = select(result, constraints={"accuracy": MaxDrop(drop)},
                     minimize="power", axis="all_layers")
        assert new is legacy
    assert select(result, {"accuracy": MaxDrop(-1.0)},
                  minimize="power", axis="all_layers") is None


def test_select_with_cost_constraint_and_maximize():
    result = _result()
    # delay ceiling rules out "cheap"
    p = select(result, constraints={"accuracy": MaxDrop(0.5),
                                    "delay": AtMost(1.0)},
               minimize="power", axis="all_layers")
    assert p.multiplier == "cheapest"
    # maximize accuracy under a power ceiling
    p = select(result, constraints={"power": AtMost(0.6)},
               maximize="accuracy", axis="all_layers")
    assert p.multiplier == "cheap"
    p = select(result, constraints={"accuracy": AtLeast(0.95)},
               minimize="power", axis="all_layers")
    assert p is None


def test_select_requires_exactly_one_direction():
    result = _result()
    with pytest.raises(ValueError):
        select(result, minimize="power", maximize="accuracy")
    with pytest.raises(ValueError):
        select(result)
    with pytest.raises(UnknownObjectiveError):
        select(result, constraints={"bogus": AtMost(1.0)},
               minimize="power")


def test_satisfies_maxdrop_without_result_raises_value_error():
    """The bare-number shorthand (= MaxDrop) needs a baseline; calling
    satisfies without the result must fail with a usable ValueError,
    not an AttributeError on None."""
    from repro.approx.objectives import satisfies
    p = DesignPoint("m", "all", 0.9, 0.5, 0.5, 1.0)
    with pytest.raises(ValueError, match="baseline"):
        satisfies(p, "accuracy", 0.02)
    with pytest.raises(ValueError, match="baseline"):
        satisfies(p, "accuracy", MaxDrop(0.02))
    # absolute constraints need no baseline
    assert satisfies(p, "accuracy", AtLeast(0.8))
    assert satisfies(p, "power", AtMost(0.6))


def test_bare_number_constraint_is_maxdrop():
    result = _result()
    a = select(result, {"accuracy": 0.02}, minimize="power",
               axis="all_layers")
    b = select(result, {"accuracy": MaxDrop(0.02)}, minimize="power",
               axis="all_layers")
    assert a is b


# ----------------------------------------------------------------------
# Serialization symmetry (ExploreResult/DesignPoint round-trip)
# ----------------------------------------------------------------------
def test_design_point_json_round_trip():
    from repro.approx.specs import BackendSpec
    p = DesignPoint("mul8u_trunc6", "s1_b0_conv1", 0.87, 0.93, 0.6, 0.2,
                    spec=BackendSpec(mode="lut",
                                     multiplier="mul8u_trunc6"),
                    errors={"mae": 12.0, "wce": 99.0},
                    metrics={"accuracy": 0.87, "logit_mae": 0.01},
                    costs={"area": 0.8, "delay": 1.1})
    blob = json.dumps(p.to_dict(), sort_keys=True)
    q = DesignPoint.from_dict(json.loads(blob))
    assert q == p


def test_hetero_design_point_round_trip_preserves_assignment_order():
    assignment = {"conv2": "mul8u_trunc6", "conv1": "mul8u_exact"}
    p = DesignPoint.from_assignment(assignment, 0.9, 0.7,
                                    metrics={"accuracy": 0.9},
                                    costs={"area": 0.7, "delay": 1.0})
    q = DesignPoint.from_dict(json.loads(json.dumps(p.to_dict())))
    assert q == p
    assert [l for l, _ in q.assignment] == ["conv2", "conv1"]


def test_explore_result_json_round_trip():
    result = _result()
    result.per_layer = [DesignPoint("m", "conv1", 0.8, 0.9, 0.5, 0.3)]
    result.heterogeneous = [DesignPoint.from_assignment(
        {"conv1": "mul8u_exact"}, 0.9, 0.95)]
    result.selected = result.all_layers[1]
    result.objectives = ("accuracy", "power", "delay")
    blob = json.dumps(result.to_json_dict(), sort_keys=True)
    back = ExploreResult.from_json_dict(json.loads(blob))
    assert back.to_json_dict() == result.to_json_dict()
    assert back.all_layers == result.all_layers
    assert back.selected == result.selected
    assert back.objectives == result.objectives
    assert back.primary == result.primary


def test_round_trip_restores_min_primary_direction():
    """A restored min-primary exploration must keep its quality-bound
    direction even in a process that never constructed the workload
    (the metric axis is re-registered from the serialized
    directions)."""
    from repro.approx import objectives as obj_mod
    name = "restore_only_metric"
    ensure_objective(name, "min")
    result = ExploreResult(
        baseline_accuracy=0.006,
        all_layers=[
            DesignPoint("good", "all", 0.010, 0.9, 0.9, 1.0,
                        metrics={name: 0.010}),
            DesignPoint("terrible", "all", 0.500, 0.3, 0.3, 1.0,
                        metrics={name: 0.500})],
        baseline_metrics={name: 0.006},
        objectives=(name, "power"), primary=name)
    in_process = [p.multiplier for p in result.within(0.05)]
    blob = json.dumps(result.to_json_dict())
    # simulate a fresh process: the workload-registered axis is gone
    del obj_mod._REGISTRY[name]
    back = ExploreResult.from_json_dict(json.loads(blob))
    assert get_objective(name).direction == "min"
    assert [p.multiplier for p in back.within(0.05)] == in_process \
        == ["good"]
    assert [p.multiplier for p in back.pareto()] \
        == [p.multiplier for p in result.pareto()]
    del obj_mod._REGISTRY[name]


def test_compose_assignments_min_direction_prefers_better_quality():
    """Shortlist tie-break on equal predicted power must prefer BETTER
    predicted quality in the primary's own direction — for a
    min-primary, the LOWER predicted value."""
    import numpy as np

    from repro.approx.dse import compose_assignments
    from repro.approx.resilience import LayerComponents

    comp = LayerComponents(
        layers=("a", "b"), multipliers=("m0", "m1"),
        quality=np.array([[1.0, 1.3],     # layer a: m1 hurts by 0.3
                          [1.0, 1.1]]),   # layer b: m1 hurts by 0.1
        rel_power=np.array([1.0, 0.5]),
        counts=(1, 1), total_count=2, baseline=1.0, direction="min")
    rows = [tuple(r.tolist())
            for r in compose_assignments(comp, top_k=4)]
    # both power-0.75 assignments present; the lower-predicted-MAE one
    # (m0@a, m1@b → drop 0.1) must sort before (m1@a, m0@b → drop 0.3)
    assert rows.index((0, 1)) < rows.index((1, 0))


def test_from_json_dict_accepts_pre_refactor_schema():
    """Dicts written before §2.7 lack metrics/costs/objectives."""
    old = {"baseline_accuracy": 0.9,
           "all_layers": [{"multiplier": "m", "layer": "all",
                           "accuracy": 0.8, "network_rel_power": 0.5,
                           "multiplier_rel_power": 0.5,
                           "mult_share": 1.0, "spec": None,
                           "errors": {}, "assignment": None,
                           "mode": "lut", "variant": "ref"}],
           "per_layer": [], "heterogeneous": [], "selected": None}
    back = ExploreResult.from_json_dict(old)
    assert back.baseline_accuracy == 0.9
    assert back.objectives == ("accuracy", "power")
    assert back.all_layers[0].metrics == {}
