"""Model-zoo resilience profiler tests (DESIGN.md §2.12):
profile_architecture end to end on a tiny dense LM, selection under the
declarative MaxDrop constraint, ranking sanity, and serialization."""
import jax.numpy as jnp
import pytest

from repro.approx.layers import ApproxPolicy
from repro.approx.modules import ModuleMap
from repro.approx.profiles import (ArchProfile, ModuleRow,
                                   profile_architecture, profile_zoo)
from repro.approx.specs import BackendSpec
from repro.approx.workload import lm_fidelity
from repro.core.families import truncated_multiplier
from repro.core.library import ApproxLibrary
from repro.core.seeds import array_multiplier
from repro.models.common import LMConfig

MULTS = ["mul8u_exact", "mul8u_trunc6", "mul8u_trunc3"]


@pytest.fixture(scope="module")
def lib():
    lib = ApproxLibrary()
    exact = array_multiplier(8)
    lib.add_netlist(exact, "multiplier", 8, "exact", exact,
                    name="mul8u_exact")
    for k in (2, 5):
        lib.add_netlist(truncated_multiplier(8, k), "multiplier", 8,
                        "truncation", exact)
    return lib


@pytest.fixture(scope="module")
def tiny_cfg():
    return LMConfig(name="tiny-dense", family="dense", n_layers=2,
                    d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                    vocab=128, head_dim=16, dtype=jnp.float32,
                    remat=False, loss_chunk=16)


@pytest.fixture(scope="module")
def profile(tiny_cfg, lib):
    wl = lm_fidelity(tiny_cfg, batch=2, seq_len=8, n_batches=1)
    mmap = ModuleMap.for_config(tiny_cfg, batch=2, seq_len=8)
    return profile_architecture(wl, mmap, lib, MULTS, arch="tiny-dense",
                                model_family="dense", max_drop=0.05), \
        wl, mmap


def test_profile_sweeps_every_family_x_multiplier(profile):
    prof, _wl, mmap = profile
    assert prof.modules == mmap.modules
    assert len(prof.rows) == len(mmap.modules) * len(MULTS)
    seen = {(r.module, r.multiplier) for r in prof.rows}
    assert seen == {(f, m) for f in mmap.modules for m in MULTS}
    for r in prof.rows:
        assert r.quality_drop >= 0.0
        assert 0.0 < r.mult_share < 1.0
        # single-family exact rows sit at golden power
        if r.multiplier == "mul8u_exact":
            assert r.network_rel_power == pytest.approx(1.0)


def test_profile_ranking_orders_by_mean_drop(profile):
    prof, _wl, _mmap = profile
    assert set(prof.ranking) == set(prof.modules)
    mean = {f: sum(r.quality_drop for r in prof.rows if r.module == f)
            / len(MULTS) for f in prof.modules}
    drops = [mean[f] for f in prof.ranking]
    assert drops == sorted(drops)


def test_profile_selection_satisfies_max_drop(profile):
    prof, wl, mmap = profile
    assert prof.selected is not None
    assert set(prof.selected["modules"]) == set(mmap.modules)
    assert prof.selected["quality_drop"] <= prof.max_drop + 1e-9
    assert prof.selected["power"] <= 1.0 + 1e-9
    # the selected per-module policy re-measures to its recorded metrics
    lowered = mmap.lower(prof.selected["modules"])
    assert prof.selected["layers"] == lowered


def test_profile_selection_infeasible_bound_falls_back_to_exact(
        tiny_cfg, lib):
    wl = lm_fidelity(tiny_cfg, batch=2, seq_len=8, n_batches=1)
    mmap = ModuleMap.for_config(tiny_cfg, batch=2, seq_len=8)
    prof = profile_architecture(wl, mmap, lib, MULTS, max_drop=0.0)
    # drop <= 0 still admits the all-exact uniform (drop == 0, power 1)
    assert prof.selected is not None
    assert set(prof.selected["modules"].values()) == {"mul8u_exact"}


def test_profile_round_trips_through_json(profile):
    import json
    prof, _wl, _mmap = profile
    zoo = profile_zoo({"tiny-dense": prof})
    blob = json.loads(json.dumps(zoo))
    back = ArchProfile.from_dict(blob["archs"]["tiny-dense"])
    assert back.ranking == prof.ranking
    assert back.selected == prof.selected
    assert [r.to_dict() for r in back.rows] \
        == [r.to_dict() for r in prof.rows]
    assert set(blob["family_mean_drop"]) == set(prof.modules)


def test_profile_baseline_is_golden_int8(profile):
    prof, wl, _mmap = profile
    golden = ApproxPolicy(default=BackendSpec.golden().materialize())
    assert prof.baseline_metrics == wl.measure(golden)
    assert prof.primary == "logit_mae"
    assert prof.direction == "min"
