"""Width-aware quantization regression tests (DESIGN.md §2.6).

``calibrate``/``quantize``/``dequantize`` are parametric in ``bits``:
round-trip error must shrink with width (bounded by scale/2 per
element), zero points must stay inside the code range, and the 8-bit
path must remain bit-identical to the historical uint8 arithmetic.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.quant import (QuantParams, calibrate, dequantize,
                                fake_quant, quantize)

WIDTHS = (8, 12, 16)
RNG = np.random.default_rng(11)


@pytest.mark.parametrize("bits", WIDTHS)
def test_round_trip_error_bounded_by_half_scale(bits):
    x = jnp.asarray(RNG.normal(size=(64, 32)).astype(np.float32) * 3.0)
    qp = calibrate(x, bits=bits)
    err = np.abs(np.asarray(dequantize(quantize(x, qp), qp) - x))
    assert err.max() <= float(qp.scale) * 0.5 + 1e-6
    # the range covers the tensor, so scale ~ span / (2^bits - 1)
    span = float(jnp.max(x) - jnp.min(x))
    assert float(qp.scale) <= span / (2 ** bits - 1) * 1.001


def test_wider_widths_strictly_reduce_round_trip_error():
    x = jnp.asarray(RNG.normal(size=(128, 16)).astype(np.float32))
    maes = [float(np.abs(np.asarray(fake_quant(x, bits=b) - x)).mean())
            for b in WIDTHS]
    assert maes[1] < maes[0] / 4
    assert maes[2] < maes[1] / 4


@pytest.mark.parametrize("bits", WIDTHS)
def test_zero_point_and_codes_stay_in_range(bits):
    qmax = 2 ** bits - 1
    for scale in (0.01, 1.0, 1000.0):
        for shift in (-5.0, 0.0, 7.0):
            x = jnp.asarray(
                RNG.normal(size=(33, 7)).astype(np.float32) * scale
                + shift)
            qp = calibrate(x, bits=bits)
            zp = int(qp.zero_point)
            assert 0 <= zp <= qmax
            q = np.asarray(quantize(x, qp))
            assert q.min() >= 0 and q.max() <= qmax


@pytest.mark.parametrize("bits", WIDTHS)
def test_all_zeros_edge_case(bits):
    x = jnp.zeros((8, 8), jnp.float32)
    qp = calibrate(x, bits=bits)
    assert float(qp.scale) > 0                      # eps floor, no NaN
    assert int(qp.zero_point) == 0
    assert np.asarray(dequantize(quantize(x, qp), qp)).max() == 0.0


@pytest.mark.parametrize("bits", WIDTHS)
@pytest.mark.parametrize("c", [4.25, -3.0])
def test_constant_tensor_edge_case(bits, c):
    x = jnp.full((5, 9), c, jnp.float32)
    qp = calibrate(x, bits=bits)
    q = np.asarray(quantize(x, qp))
    qmax = 2 ** bits - 1
    assert q.min() >= 0 and q.max() <= qmax
    # constant tensors round-trip exactly: the grid [min(x,0), max(x,0)]
    # contains both 0 and c on code-point boundaries
    back = np.asarray(dequantize(quantize(x, qp), qp))
    np.testing.assert_allclose(back, c, rtol=1e-5)


def test_bits8_bit_identical_to_historical_uint8_path():
    """The refactored width-generic calibrate at bits=8 must reproduce
    the pre-refactor arithmetic EXACTLY (same f32 ops, qmax == 255.0
    exactly)."""
    x = jnp.asarray(RNG.normal(size=(40, 13)).astype(np.float32) * 2.5
                    + 0.7)
    qp = calibrate(x, bits=8)
    assert float(qp.qmax) == 255.0
    # historical formulas, verbatim
    lo = jnp.minimum(jnp.min(x), 0.0).astype(jnp.float32)
    hi = jnp.maximum(jnp.max(x), 0.0).astype(jnp.float32)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-8)
    zp = jnp.clip(jnp.round(-lo / scale), 0, 255).astype(jnp.int32)
    assert float(qp.scale) == float(scale)
    assert int(qp.zero_point) == int(zp)
    old_q = jnp.clip(jnp.round(x / scale) + zp, 0, 255).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(quantize(x, qp)),
                                  np.asarray(old_q))


def test_traced_bits_matches_static_bits():
    """Mixed-width banks pass ``bits`` as a traced per-lane scalar;
    the result must equal static calibration at the same width."""
    import jax
    x = jnp.asarray(RNG.normal(size=(16, 16)).astype(np.float32))

    def quant_codes(bits):
        qp = calibrate(x, bits=bits)
        return quantize(x, qp)

    for bits in WIDTHS:
        static = np.asarray(quant_codes(bits))
        traced = np.asarray(jax.jit(quant_codes)(jnp.int32(bits)))
        np.testing.assert_array_equal(static, traced)


def test_quantparams_default_is_8bit():
    qp = QuantParams(scale=jnp.float32(1.0), zero_point=jnp.int32(0))
    assert float(qp.qmax) == 255.0
