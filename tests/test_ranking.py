"""Shared rank-correlation helpers (repro.approx.ranking) validated
against scipy on small cases — the satellite that lets the surrogate
fidelity gates and the library rank analyses share one tie-aware
Spearman/Kendall implementation."""
import numpy as np
import pytest

from repro.approx.ranking import (kendall, per_layer_spearman, rankdata,
                                  spearman)

scipy_stats = pytest.importorskip("scipy.stats")


CASES = [
    [1.0, 2.0, 3.0, 4.0, 5.0],
    [5.0, 3.0, 1.0, 4.0, 2.0],
    [1.0, 2.0, 2.0, 3.0],            # interior tie
    [0.0, 0.0, 1.0, 1.0, 2.0],       # tied groups
    [3.5, -1.0, 2.0, 2.0, 2.0, 9.0],
    list(np.random.default_rng(0).normal(size=12)),
    list(np.random.default_rng(1).integers(0, 4, size=10).astype(float)),
]


@pytest.mark.parametrize("x", CASES)
def test_rankdata_matches_scipy(x):
    np.testing.assert_allclose(
        rankdata(x), scipy_stats.rankdata(x, method="average"))


@pytest.mark.parametrize("i", range(len(CASES) - 1))
def test_spearman_matches_scipy(i):
    x, y = CASES[i], CASES[i + 1][:len(CASES[i])]
    x, y = x[:len(y)], y[:len(x)]
    expected = scipy_stats.spearmanr(x, y).statistic
    assert spearman(x, y) == pytest.approx(expected, abs=1e-12)


@pytest.mark.parametrize("i", range(len(CASES) - 1))
def test_kendall_matches_scipy(i):
    x, y = CASES[i], CASES[i + 1][:len(CASES[i])]
    x, y = x[:len(y)], y[:len(x)]
    expected = scipy_stats.kendalltau(x, y).statistic
    assert kendall(x, y) == pytest.approx(expected, abs=1e-12)


def test_perfect_and_inverted_orderings():
    x = [1.0, 2.0, 3.0, 4.0]
    assert spearman(x, x) == pytest.approx(1.0)
    assert spearman(x, x[::-1]) == pytest.approx(-1.0)
    assert kendall(x, x) == pytest.approx(1.0)
    assert kendall(x, x[::-1]) == pytest.approx(-1.0)


def test_constant_inputs_are_nan():
    # no ordering to correlate: scipy's convention, and the explicit
    # contract the fidelity gates filter on
    assert np.isnan(spearman([1.0, 1.0, 1.0], [1.0, 2.0, 3.0]))
    assert np.isnan(kendall([1.0, 2.0, 3.0], [2.0, 2.0, 2.0]))
    assert np.isnan(spearman([1.0], [2.0]))
    assert np.isnan(kendall([], []))


def test_length_mismatch_raises():
    with pytest.raises(ValueError):
        spearman([1.0, 2.0], [1.0, 2.0, 3.0])
    with pytest.raises(ValueError):
        rankdata(np.zeros((2, 2)))


def test_per_layer_spearman_keys_and_values():
    pred = np.array([[1.0, 2.0, 3.0], [3.0, 2.0, 1.0], [1.0, 1.0, 1.0]])
    meas = np.array([[10.0, 20.0, 30.0], [1.0, 2.0, 3.0], [0.0, 1.0, 2.0]])
    got = per_layer_spearman(pred, meas, ["a", "b", "c"])
    assert got["a"] == pytest.approx(1.0)
    assert got["b"] == pytest.approx(-1.0)
    assert np.isnan(got["c"])       # constant predicted row
    with pytest.raises(ValueError):
        per_layer_spearman(pred, meas[:2], ["a", "b", "c"])
