"""Zoo-registry coverage: every config module under
``src/repro/configs`` is importable, registered in ``configs.ARCHS``,
resolvable through ``models.registry.model_fns``, and produces a
forward pass on tiny shapes (abstractly traced — catches configs that
silently rot without burning FLOPs on 10 models).  One real forward
runs per model *family* as the numeric smoke check."""
import importlib
import pathlib
import pkgutil

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.configs import ARCHS, get_config
from repro.models.registry import (input_extras, model_fns,
                                   probe_layer_tags, prompt_extra_len)

CONFIG_DIR = pathlib.Path(configs.__file__).parent


def _config_modules():
    return sorted(m.name for m in pkgutil.iter_modules([str(CONFIG_DIR)]))


def test_every_config_module_is_registered():
    modules = _config_modules()
    assert modules, "no config modules found"
    registered = {mod for mod in ARCHS.values()}
    for name in modules:
        mod = importlib.import_module(f"repro.configs.{name}")
        if not callable(getattr(mod, "config", None)):
            continue            # support modules (e.g. shapes)
        cfg = mod.config()
        if not hasattr(cfg, "family"):
            continue            # the paper's ResNet family: not an LM
                                # registry entry (covered below)
        assert name in registered, (
            f"configs/{name}.py defines a config() but is not "
            "registered in configs.ARCHS — the zoo entry would "
            "silently rot")


def test_resnet_cifar_config_produces_a_forward_pass():
    from repro.configs.resnet_cifar import DEPTHS, config
    from repro.models import resnet

    cfg = config(DEPTHS[0])
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    logits = resnet.forward(
        params, np.zeros((2, cfg.image_size, cfg.image_size, 3),
                         np.float32), cfg)
    assert logits.shape == (2, cfg.n_classes)


def test_every_registered_arch_resolves():
    for arch in ARCHS:
        cfg = get_config(arch)
        assert cfg.name == arch
        reduced = cfg.reduced()
        assert reduced.n_layers <= cfg.n_layers
        model_fns(reduced)      # family dispatch must succeed


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_registered_arch_traces_a_forward_pass(arch):
    cfg = get_config(arch).reduced()
    fns = model_fns(cfg)
    params = jax.eval_shape(lambda k: fns.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    tags = probe_layer_tags(cfg, params)    # traces one full prefill
    assert tags, f"{arch}: forward pass hit no matmul call sites"


def test_one_real_forward_per_family():
    by_family = {}
    for arch in sorted(ARCHS):
        cfg = get_config(arch).reduced()
        by_family.setdefault(cfg.family, arch)
    seq = 4
    for arch in by_family.values():
        cfg = get_config(arch).reduced()
        fns = model_fns(cfg)
        params = fns.init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": np.zeros((1, seq), np.int32)}
        batch.update(input_extras(cfg, 1))
        cache = fns.init_cache(cfg, 1, seq + prompt_extra_len(cfg, batch))
        logits, _ = fns.forward_prefill(params, batch, cache, cfg)
        assert logits.shape == (1, cfg.vocab)
        assert bool(jax.numpy.all(jax.numpy.isfinite(
            logits.astype(jax.numpy.float32))))
