"""Resilience-analysis driver + power model + synthetic data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.backend import MatmulBackend
from repro.approx.layers import ApproxPolicy
from repro.approx.power import LayerPower, network_relative_power, per_layer_share
from repro.approx.resilience import all_layers_sweep, per_layer_sweep
from repro.core.library import build_default_library
from repro.data.synthetic import CifarBatches, synthetic_cifar, token_stream
from repro.models import resnet


def test_power_model():
    layers = [LayerPower("a", 100, "m1", 0.5),
              LayerPower("b", 300, "m2", 1.0)]
    assert network_relative_power(layers) == pytest.approx(
        (100 * 0.5 + 300 * 1.0) / 400)
    share = per_layer_share(layers)
    assert share["b"] == pytest.approx(0.75)


def test_synthetic_cifar_deterministic_and_learnable():
    a_img, a_lab = synthetic_cifar("train", 64, seed=1)
    b_img, b_lab = synthetic_cifar("train", 64, seed=1)
    np.testing.assert_array_equal(a_img, b_img)
    np.testing.assert_array_equal(a_lab, b_lab)
    c_img, _ = synthetic_cifar("test", 64, seed=1)
    assert not np.array_equal(a_img, c_img)
    assert a_img.min() >= 0.0 and a_img.max() <= 1.0
    # class-conditional structure: per-class mean images must differ
    m0 = a_img[a_lab == a_lab[0]].mean(axis=0)
    other = a_img[a_lab != a_lab[0]]
    assert other.size and np.abs(m0 - other.mean(axis=0)).max() > 0.02


def test_token_stream_shapes():
    t, y = token_stream(1000, 4, 16, step=0)
    assert t.shape == (4, 16) and y.shape == (4, 16)
    assert (t >= 0).all() and (t < 1000).all()
    t2, _ = token_stream(1000, 4, 16, step=0)
    np.testing.assert_array_equal(t, t2)


@pytest.fixture(scope="module")
def sweep_setup():
    lib = build_default_library("tiny")
    cfg = resnet.resnet_config(8)
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    data = CifarBatches("test", 64, 32, seed=0)
    batches = list(data.eval_batches())

    def eval_fn(policy):
        accs = []
        fwd = jax.jit(lambda p, im: resnet.forward(p, im, cfg, policy))
        for b in batches:
            logits = fwd(params, jnp.asarray(b["images"]))
            accs.append(np.mean(np.argmax(np.asarray(logits), -1)
                                == b["labels"]))
        return float(np.mean(accs))

    return lib, cfg, eval_fn


@pytest.mark.slow
def test_all_layers_sweep(sweep_setup):
    lib, cfg, eval_fn = sweep_setup
    rows = all_layers_sweep(eval_fn, resnet.layer_mult_counts(cfg),
                            ["mul8u_exact", "mul8u_trunc4"], lib,
                            mode="lut")
    by_name = {r.multiplier: r for r in rows}
    assert by_name["mul8u_exact"].network_rel_power == pytest.approx(1.0)
    assert by_name["mul8u_trunc4"].network_rel_power < 0.6
    # untrained net: accuracies near chance; just finite + in [0,1]
    for r in rows:
        assert 0.0 <= r.accuracy <= 1.0


@pytest.mark.slow
def test_per_layer_sweep_structure(sweep_setup):
    lib, cfg, eval_fn = sweep_setup
    counts = {k: v for k, v in
              list(resnet.layer_mult_counts(cfg).items())[:2]}
    rows = per_layer_sweep(eval_fn, counts, ["mul8u_trunc4"], lib,
                           mode="lut")
    assert len(rows) == 2
    shares = [r.mult_share for r in rows]
    assert all(0 < s < 1 for s in shares)
    # network power reflects only the swept layer's share
    for r in rows:
        assert r.network_rel_power > r.multiplier_rel_power
