"""Batched resilience engine: bank equivalence + trace counting.

The contract under test (DESIGN.md §2.4): a ``batch=True`` sweep over a
``LutBank`` returns bit-identical ``ResilienceRow`` accuracies to the
sequential per-policy path, while compiling O(1) programs instead of
O(n_mult)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.dse import explore
from repro.approx.layers import ApproxPolicy, bank_eval
from repro.approx.resilience import (BankableEval, all_layers_sweep,
                                     can_bank, per_layer_sweep)
from repro.approx.specs import BackendSpec, LutBank, bank_for
from repro.core.library import build_default_library
from repro.data.synthetic import CifarBatches
from repro.models import resnet

MULTS = ["mul8u_exact", "mul8u_trunc4", "mul8u_trunc2"]


@pytest.fixture(scope="module")
def lib():
    return build_default_library("tiny")


@pytest.fixture(scope="module")
def resnet_eval(lib):
    """Small ResNet-8 eval on the seed library subset, instrumented to
    count jax traces of its core."""
    cfg = resnet.resnet_config(8)
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    data = CifarBatches("test", 32, 32, seed=0)
    batch = next(iter(data.eval_batches()))
    images = jnp.asarray(batch["images"])
    labels = jnp.asarray(batch["labels"])
    traces = []

    def traceable(policy):
        traces.append(1)          # runs once per jax trace, not per eval
        logits = resnet.forward(params, images, cfg, policy)
        return jnp.mean((jnp.argmax(logits, -1) == labels
                         ).astype(jnp.float32))

    def fn(policy):
        return float(jax.jit(lambda: traceable(policy))())

    return cfg, BankableEval(fn=fn, traceable=traceable), traces


@pytest.mark.slow
def test_all_layers_batched_bit_identical_and_one_trace(lib, resnet_eval):
    cfg, eval_fn, traces = resnet_eval
    counts = resnet.layer_mult_counts(cfg)
    seq = all_layers_sweep(eval_fn, counts, MULTS, lib, mode="lut")
    traces.clear()
    bat = all_layers_sweep(eval_fn, counts, MULTS, lib, mode="lut",
                           batch=True)
    assert len(traces) == 1, "batched sweep must compile O(1) programs"
    assert [r.multiplier for r in bat] == [r.multiplier for r in seq]
    assert [r.accuracy for r in bat] == [r.accuracy for r in seq]
    for s, b in zip(seq, bat):
        assert s.network_rel_power == b.network_rel_power
        assert s.spec == b.spec and s.errors == b.errors


@pytest.mark.slow
def test_per_layer_batched_bit_identical(lib, resnet_eval):
    cfg, eval_fn, traces = resnet_eval
    counts = dict(list(resnet.layer_mult_counts(cfg).items())[:2])
    seq = per_layer_sweep(eval_fn, counts, MULTS[:2], lib, mode="lut")
    traces.clear()
    bat = per_layer_sweep(eval_fn, counts, MULTS[:2], lib, mode="lut",
                          batch=True)
    assert len(traces) == len(counts), "one program per layer"
    assert [(r.multiplier, r.layer, r.accuracy) for r in bat] \
        == [(r.multiplier, r.layer, r.accuracy) for r in seq]
    assert [r.mult_share for r in bat] == [r.mult_share for r in seq]


def test_batch_requires_bankable_eval(lib):
    with pytest.raises(ValueError, match="BankableEval"):
        all_layers_sweep(lambda p: 0.5, {"a": 1}, MULTS, lib,
                         mode="lut", batch=True)
    assert not can_bank(lambda p: 0.5, "lut")
    assert not can_bank(BankableEval(fn=lambda p: 0.5,
                                     traceable=lambda p: jnp.float32(0.5)),
                        "lowrank")


@pytest.mark.slow
def test_explore_batch_matches_sequential_and_seeds_cache(lib, resnet_eval):
    cfg, eval_fn, _ = resnet_eval
    counts = dict(list(resnet.layer_mult_counts(cfg).items())[:2])
    res_seq = explore(eval_fn, counts, lib, multipliers=MULTS[:2],
                      mode="lut")
    cache: dict = {}
    res_bat = explore(eval_fn, counts, lib, multipliers=MULTS[:2],
                      mode="lut", batch=True, cache=cache)
    assert res_bat.baseline_accuracy == res_seq.baseline_accuracy
    assert [(p.multiplier, p.layer, p.accuracy)
            for p in res_bat.all_layers + res_bat.per_layer] \
        == [(p.multiplier, p.layer, p.accuracy)
            for p in res_seq.all_layers + res_seq.per_layer]
    # batched results were seeded into the cache under sequential keys:
    # a sequential re-exploration over the same cache runs zero evals.
    calls = [0]

    def counting(policy):
        calls[0] += 1
        return 0.0

    explore(counting, counts, lib, multipliers=MULTS[:2], mode="lut",
            cache=cache)
    assert calls[0] == 0


def test_explore_batch_falls_back_when_not_bankable(lib):
    """batch=True with a plain callable (or unbankable mode) silently
    uses the sequential path — same results, no error."""
    calls = [0]
    x = jnp.asarray(np.linspace(-1, 1, 64).reshape(8, 8), jnp.float32)
    w = jnp.asarray(np.eye(8), jnp.float32)

    def eval_fn(policy):
        calls[0] += 1
        return float(jnp.mean(policy.matmul("a", x, w)))

    res = explore(eval_fn, {"a": 10}, lib, multipliers=MULTS,
                  mode="lut", per_layer=False, batch=True)
    assert calls[0] == 1 + len(MULTS)      # baseline + one per multiplier
    assert len(res.all_layers) == len(MULTS)


def test_lut_bank_construction_and_cache(lib):
    bank = bank_for(MULTS, lib)
    assert bank.n_mult == len(MULTS) and bank.luts.shape == (3, 256, 256)
    assert bank_for(MULTS, lib) is bank, "bank cache must dedupe"
    assert bank_for(MULTS[:2], lib) is not bank
    spec = bank.spec(1)
    assert spec == BackendSpec(mode="lut", multiplier="mul8u_trunc4")
    # exact lane really is the exact product table
    i = MULTS.index("mul8u_exact")
    a, b = np.meshgrid(np.arange(256), np.arange(256), indexing="ij")
    np.testing.assert_array_equal(bank.luts[i], a * b)
    with pytest.raises(ValueError, match="256"):
        LutBank(names=("x",), luts=np.zeros((1, 16, 16), np.int32))
    with pytest.raises(ValueError, match="name"):
        LutBank(names=("x", "y"), luts=np.zeros((1, 256, 256), np.int32))


def test_bank_eval_sharded(lib):
    """bank_eval with an explicit bank sharding (single-device mesh
    here) computes the same accuracies as the unsharded path."""
    from repro.launch.mesh import bank_sharding, sweep_mesh

    bank = bank_for(MULTS, lib)
    x = jnp.asarray(np.linspace(-2, 2, 96).reshape(12, 8), jnp.float32)
    w = jnp.asarray(np.ones((8, 4)), jnp.float32)

    def fn(policy):
        return jnp.mean(policy.matmul("a", x, w))

    mesh = sweep_mesh()
    sharding = bank_sharding(bank.n_mult, mesh)
    got = np.asarray(bank_eval(fn, bank, sharding=sharding))
    want = np.asarray(bank_eval(fn, bank))
    np.testing.assert_array_equal(got, want)
