"""Continuous-batching serving path (DESIGN.md §2.8): mixed-policy
continuous-batch vs sequential ``generate`` bit-identity, the O(1)
compiled-programs gate, paged-KV vs contiguous-cache equivalence,
scheduler admission/retire/join invariants, and the ``Engine._steps``
LRU pinning regression."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.layers import ApproxPolicy
from repro.approx.specs import BackendSpec, PolicyBank, policy_assignment
from repro.core.families import truncated_multiplier
from repro.core.library import ApproxLibrary
from repro.core.seeds import array_multiplier
from repro.models.common import LMConfig
from repro.models.registry import (input_extras, model_fns,
                                   probe_layer_tags, prompt_extra_len)
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig
from repro.serve.kv_cache import PagedKVCache, cache_layout
from repro.serve.scheduler import Scheduler

MULTS = ["mul8u_exact", "mul8u_trunc6", "mul8u_trunc5", "mul8u_trunc3"]


@pytest.fixture(scope="module")
def lib():
    lib = ApproxLibrary()
    exact = array_multiplier(8)
    lib.add_netlist(exact, "multiplier", 8, "exact", exact,
                    name="mul8u_exact")
    for k in (2, 3, 5):
        lib.add_netlist(truncated_multiplier(8, k), "multiplier", 8,
                        "truncation", exact)
    return lib


@pytest.fixture(scope="module")
def tiny_cfg():
    return LMConfig(name="tiny-dense", family="dense", n_layers=2,
                    d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                    vocab=128, head_dim=16, dtype=jnp.float32,
                    remat=False, loss_chunk=16)


@pytest.fixture(scope="module")
def tiny_params(tiny_cfg):
    return model_fns(tiny_cfg).init_params(jax.random.PRNGKey(0),
                                           tiny_cfg)


def _uniform(mult):
    return ApproxPolicy(default=BackendSpec(
        mode="lut", multiplier=mult, ste=False)).to_json()


def _mixed_requests(vocab, rng):
    """4 distinct policies (incl. engine default and a heterogeneous
    per-layer one), mixed greedy/sampled."""
    hetero = ApproxPolicy(
        default=BackendSpec(mode="lut", multiplier="mul8u_trunc5",
                            ste=False),
        overrides=[("attn.*", BackendSpec(mode="lut",
                                          multiplier="mul8u_trunc6",
                                          ste=False))]).to_json()
    serves = [
        ServeConfig(max_new_tokens=5, policy=None),
        ServeConfig(max_new_tokens=7, policy=_uniform("mul8u_trunc6"),
                    temperature=0.8, seed=3),
        ServeConfig(max_new_tokens=4, policy=_uniform("mul8u_trunc3")),
        ServeConfig(max_new_tokens=6, policy=hetero, temperature=1.1,
                    seed=9),
    ]
    prompts = [rng.integers(0, vocab, (int(rng.integers(3, 9)),)
                            ).astype(np.int32) for _ in serves]
    return prompts, serves


# ----------------------------------------------------------------------
# Tentpole: mixed-policy bit-identity + O(1) compiled programs
# ----------------------------------------------------------------------
def test_mixed_policy_bit_identity_and_o1_traces(tiny_cfg, tiny_params,
                                                 lib):
    eng = ContinuousEngine(tiny_cfg, tiny_params, library=lib,
                           multipliers=MULTS, n_slots=3, capacity=32,
                           block_size=4)
    rng = np.random.default_rng(0)
    prompts, serves = _mixed_requests(tiny_cfg.vocab, rng)
    assert len({s.policy for s in serves}) >= 4    # N >= 4 distinct
    rids = [eng.submit(p, s) for p, s in zip(prompts, serves)]
    out = eng.run()
    # continuous batching really happened: 4 requests over 3 slots
    assert eng.scheduler.stats()["finished"] == 4
    # O(1) compiled programs: ONE decode trace for 4 distinct policies
    # over 3 concurrent slots (prompts share no length -> prefill
    # traces track distinct shapes, not policies)
    assert eng.trace_counts["decode"] == 1
    assert eng.trace_counts["bank_builds"] == 1
    for p, s, rid in zip(prompts, serves, rids):
        ref = Engine(tiny_cfg, tiny_params, eng.lane_policy(s),
                     library=lib).generate(p[None], s)[0]
        np.testing.assert_array_equal(out[rid], ref, err_msg=rid)


def test_bank_growth_retraces_once_then_stable(tiny_cfg, tiny_params,
                                               lib):
    eng = ContinuousEngine(tiny_cfg, tiny_params, library=lib,
                           n_slots=2, capacity=24, block_size=4)
    prompt = np.arange(4, dtype=np.int32) + 1
    eng.submit(prompt, ServeConfig(max_new_tokens=3))
    eng.run()
    assert eng.trace_counts["bank_builds"] == 1
    # new multiplier -> bank grows, decode recompiles ONCE
    eng.submit(prompt, ServeConfig(max_new_tokens=3,
                                   policy=_uniform("mul8u_trunc6")))
    eng.run()
    assert eng.trace_counts["bank_builds"] == 2
    decode_after_growth = eng.trace_counts["decode"]
    # same policy set again: no further traces
    eng.submit(prompt, ServeConfig(max_new_tokens=3,
                                   policy=_uniform("mul8u_trunc6")))
    eng.submit(prompt, ServeConfig(max_new_tokens=3))
    eng.run()
    assert eng.trace_counts["decode"] == decode_after_growth


def test_fixed_bank_rejects_unknown_multiplier(tiny_cfg, tiny_params,
                                               lib):
    eng = ContinuousEngine(tiny_cfg, tiny_params, library=lib,
                           multipliers=["mul8u_exact"], n_slots=2,
                           capacity=16, block_size=4)
    with pytest.raises(ValueError, match="fixed bank"):
        eng.submit(np.arange(4, dtype=np.int32),
                   ServeConfig(policy=_uniform("mul8u_trunc6")))


def test_non_lut_policy_rejected_at_submit(tiny_cfg, tiny_params, lib):
    eng = ContinuousEngine(tiny_cfg, tiny_params, library=lib,
                           n_slots=2, capacity=16, block_size=4)
    f32 = ApproxPolicy(default=BackendSpec(mode="f32")).to_json()
    with pytest.raises(ValueError, match="mode"):
        eng.submit(np.arange(4, dtype=np.int32),
                   ServeConfig(policy=f32))


# ----------------------------------------------------------------------
# Paged KV cache
# ----------------------------------------------------------------------
def test_cache_layout_identifies_sequence_axes(tiny_cfg):
    fns = model_fns(tiny_cfg)
    layout = cache_layout(fns, tiny_cfg, 16)
    # dense decoder: k/v sequence leaves + one pos scalar
    assert layout.capacity == 16
    assert len(layout.seq_positions) == 2
    assert len(layout.dense_positions) == 1
    for p in layout.seq_positions:
        assert layout.shapes[p][layout.seq_axes[p]] == 16


def test_paged_vs_contiguous_cache_equivalence(tiny_cfg, tiny_params):
    """write_prefill + gather_slot round-trips the contiguous prefill
    cache exactly wherever attention can see it (rows < length)."""
    fns = model_fns(tiny_cfg)
    capacity, length = 16, 6
    cache = fns.init_cache(tiny_cfg, 1, capacity)
    batch = {"tokens": jnp.arange(length, dtype=jnp.int32)[None] + 1}
    logits, cache = fns.forward_prefill(cache=cache, cfg=tiny_cfg,
                                        params=tiny_params, batch=batch)
    kv = PagedKVCache(fns, tiny_cfg, n_slots=2, capacity=capacity,
                      block_size=4)
    kv.allocate(1, capacity)
    kv.write_prefill(1, cache, length)
    back = kv.gather_slot(1)
    flat_a, td_a = jax.tree_util.tree_flatten(cache)
    flat_b, td_b = jax.tree_util.tree_flatten(back)
    assert td_a == td_b
    for a, b, t in zip(flat_a, flat_b, kv.layout.seq_axes):
        if t is None:
            np.testing.assert_array_equal(a, b)
        else:
            a_rows = jnp.moveaxis(a, t, 0)[:length]
            b_rows = jnp.moveaxis(b, t, 0)[:length]
            np.testing.assert_array_equal(a_rows, b_rows)
    # decode logits through the paged view match the contiguous cache
    tok = jnp.array([7], jnp.int32)
    ref_logits, _ = fns.forward_decode(tiny_params, tok, cache, tiny_cfg)
    got_logits, _ = fns.forward_decode(tiny_params, tok, back, tiny_cfg)
    np.testing.assert_array_equal(np.asarray(ref_logits),
                                  np.asarray(got_logits))


def test_allocator_free_list_round_trip(tiny_cfg):
    fns = model_fns(tiny_cfg)
    kv = PagedKVCache(fns, tiny_cfg, n_slots=3, capacity=16,
                      block_size=4)
    assert kv.n_free_blocks == 12
    kv.allocate(0, 9)                   # ceil(9/4) = 3 blocks
    kv.allocate(2, 16)
    assert kv.n_free_blocks == 12 - 3 - 4
    with pytest.raises(RuntimeError, match="already holds"):
        kv.allocate(0, 4)
    kv.release(0)
    assert kv.n_free_blocks == 12 - 4
    kv.release(2)
    assert kv.n_free_blocks == 12
    assert (kv.block_tables == -1).all()
    with pytest.raises(ValueError, match="capacity"):
        kv.blocks_needed(17)


# ----------------------------------------------------------------------
# Scheduler invariants
# ----------------------------------------------------------------------
def test_scheduler_admission_retire_join_invariants(tiny_cfg,
                                                    tiny_params, lib):
    """More requests than slots + a KV pool too small for full slot
    occupancy: requests must join at step boundaries, hold disjoint
    blocks, and retire cleanly — invariants checked after EVERY step."""
    eng = ContinuousEngine(tiny_cfg, tiny_params, library=lib,
                           multipliers=MULTS, n_slots=3, capacity=16,
                           block_size=4, n_blocks=8)  # < 3 full slots
    rng = np.random.default_rng(1)
    serves = [ServeConfig(max_new_tokens=int(rng.integers(2, 6)),
                          policy=_uniform(MULTS[i % len(MULTS)]))
              for i in range(7)]
    rids = [eng.submit(rng.integers(0, tiny_cfg.vocab, (5,)
                                    ).astype(np.int32), s)
            for s in serves]
    max_running = 0
    while not eng.scheduler.idle:
        eng.step()
        eng.scheduler.check_invariants(eng.kv)
        max_running = max(max_running, len(eng.scheduler.running))
    assert max_running >= 2             # requests really overlapped
    # admission is FIFO; completion order may differ (varying max_new)
    assert set(eng.scheduler.finished) == set(rids)
    for rid, s in zip(rids, serves):
        assert len(eng.scheduler.finished[rid].tokens) \
            == s.max_new_tokens
    assert eng.kv.n_free_blocks == eng.kv.n_blocks
    assert eng.trace_counts["decode"] == 1


def test_scheduler_strict_fifo_admission(tiny_cfg):
    fns = model_fns(tiny_cfg)
    sched = Scheduler(n_slots=2)
    assert sched.idle
    assert sched.head() is None
    assert sched.free_slots() == [0, 1]
    with pytest.raises(RuntimeError):
        sched.admit(0)                  # nothing queued


def test_inactive_lane_scatter_does_not_corrupt_last_block(
        tiny_cfg, tiny_params, lib):
    """Regression: inactive decode lanes must not scatter their garbage
    row into the pools.  A ``-1`` write index WRAPS to the last pool
    row (negative indices are in-bounds in JAX; ``mode="drop"`` only
    drops positive out-of-range), silently corrupting whichever request
    owns the last block — visible only once allocator churn places that
    block at a low logical position of a live request."""
    eng = ContinuousEngine(tiny_cfg, tiny_params, library=lib,
                           n_slots=2, capacity=8, block_size=4,
                           n_blocks=3)
    # churn: the first request takes blocks [0, 1]; releasing appends
    # them AFTER the never-used block 2, so the next request's FIRST
    # block is the LAST block of the pools — its logical positions
    # 0..3 map to the final pool rows, inside attention's window from
    # the first decode step, while the empty second slot stays
    # inactive every step.
    eng.submit(np.arange(4, dtype=np.int32),
               ServeConfig(max_new_tokens=2))
    eng.run()
    assert eng.kv._free[0] == 2
    prompt = np.arange(4, dtype=np.int32) + 7
    serve = ServeConfig(max_new_tokens=4)
    rid = eng.submit(prompt, serve)     # allocates blocks [2, 0]
    out = eng.run()[rid]
    ref = Engine(tiny_cfg, tiny_params, eng.lane_policy(serve),
                 library=lib).generate(prompt[None], serve)[0]
    np.testing.assert_array_equal(out, ref)


def test_oversized_request_rejected(tiny_cfg, tiny_params, lib):
    eng = ContinuousEngine(tiny_cfg, tiny_params, library=lib,
                           n_slots=2, capacity=8, block_size=4)
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(np.arange(6, dtype=np.int32),
                   ServeConfig(max_new_tokens=4))


# ----------------------------------------------------------------------
# Engine._steps LRU pinning (satellite regression)
# ----------------------------------------------------------------------
def test_lru_pinning_protects_inflight_policy(tiny_cfg, tiny_params,
                                              lib):
    eng = Engine(tiny_cfg, tiny_params, library=lib)
    eng._steps_max = 2
    pinned_policy = ApproxPolicy(default=BackendSpec(
        mode="lut", multiplier="mul8u_trunc6")).materialize(lib)
    pinned_key = pinned_policy.cache_key()
    with eng._pin(pinned_key):
        eng._steps_for(pinned_policy)
        # sweep other policies through the LRU: the pinned in-flight
        # pair must survive where the old popitem(last=False) would
        # have evicted it
        for m in ("mul8u_exact", "mul8u_trunc5", "mul8u_trunc3"):
            eng._steps_for(ApproxPolicy(default=BackendSpec(
                mode="lut", multiplier=m)).materialize(lib))
            assert pinned_key in eng._steps
    # unpinned: the same sweep now evicts it
    for m in ("mul8u_exact", "mul8u_trunc5", "mul8u_trunc3"):
        eng._steps_for(ApproxPolicy(default=BackendSpec(
            mode="lut", multiplier=m)).materialize(lib))
    assert pinned_key not in eng._steps
    assert len(eng._steps) <= 2
    assert not eng._pinned               # generate() always unpins


def test_lru_overshoots_rather_than_evict_pinned(tiny_cfg, tiny_params,
                                                 lib):
    eng = Engine(tiny_cfg, tiny_params, library=lib)
    eng._steps_max = 1
    pols = [ApproxPolicy(default=BackendSpec(
        mode="lut", multiplier=m)).materialize(lib) for m in MULTS[:3]]
    import contextlib
    with contextlib.ExitStack() as stack:
        for p in pols:
            stack.enter_context(eng._pin(p.cache_key()))
            eng._steps_for(p)
        assert all(p.cache_key() in eng._steps for p in pols)
        assert len(eng._steps) >= 3      # overshoot, everything pinned


# ----------------------------------------------------------------------
# Registry serving hooks + bank assembly
# ----------------------------------------------------------------------
def test_probe_layer_tags_dense(tiny_cfg, tiny_params):
    tags = probe_layer_tags(tiny_cfg, tiny_params)
    assert set(tags) == {"attn.wq", "attn.wk", "attn.wv", "attn.wo",
                         "ffn.wi", "ffn.wg", "ffn.wo"}


def test_input_extras_and_prompt_extra_len(tiny_cfg):
    assert input_extras(tiny_cfg, 2) == {}
    assert prompt_extra_len(tiny_cfg, None) == 0


def test_policy_assignment_resolves_patterns(lib):
    layers = ("attn.wq", "attn.wo", "ffn.wi")
    pol = ApproxPolicy(
        default=BackendSpec(mode="lut", multiplier="mul8u_trunc5"),
        overrides=[("attn.*", BackendSpec(mode="lut",
                                          multiplier="mul8u_trunc6"))])
    assert policy_assignment(pol, layers) == {
        "attn.wq": "mul8u_trunc6", "attn.wo": "mul8u_trunc6",
        "ffn.wi": "mul8u_trunc5"}
    with pytest.raises(ValueError, match="block_m"):
        policy_assignment(
            ApproxPolicy(default=BackendSpec(mode="lut", block_m=64)),
            layers)


def test_policy_bank_from_policies(lib):
    layers = ("attn.wq", "ffn.wi")
    pols = [ApproxPolicy(default=BackendSpec(mode="lut",
                                             multiplier=m))
            for m in ("mul8u_trunc6", "mul8u_trunc5")]
    pb = PolicyBank.from_policies(pols, layers, library=lib)
    assert pb.n_policies == 2 and pb.layers == layers
    assert pb.assignment(0) == {"attn.wq": "mul8u_trunc6",
                                "ffn.wi": "mul8u_trunc6"}
    assert pb.assignment(1) == {"attn.wq": "mul8u_trunc5",
                                "ffn.wi": "mul8u_trunc5"}


# ----------------------------------------------------------------------
# Cross-family serving (slow: one model per registry family)
# ----------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "mamba2-780m",
                                  "whisper-large-v3", "llava-next-34b",
                                  "jamba-v0.1-52b"])
def test_families_serve_bit_identical(arch, lib):
    from repro.configs import get_config
    cfg = get_config(arch).reduced()
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousEngine(cfg, params, library=lib,
                           multipliers=MULTS[:3], n_slots=2,
                           capacity=32, block_size=4)
    rng = np.random.default_rng(2)
    serves = [ServeConfig(max_new_tokens=4,
                          policy=_uniform("mul8u_trunc6")),
              ServeConfig(max_new_tokens=5,
                          policy=_uniform("mul8u_trunc5"),
                          temperature=0.9, seed=5),
              ServeConfig(max_new_tokens=3, policy=None)]
    prompts = [rng.integers(0, cfg.vocab, (int(rng.integers(3, 7)),)
                            ).astype(np.int32) for _ in serves]
    rids = [eng.submit(p, s) for p, s in zip(prompts, serves)]
    out = eng.run()
    assert eng.trace_counts["decode"] == 1
    extras = input_extras(cfg, 1) or None
    for p, s, rid in zip(prompts, serves, rids):
        ref = Engine(cfg, params, eng.lane_policy(s),
                     library=lib).generate(p[None], s, extras=extras)[0]
        np.testing.assert_array_equal(out[rid], ref,
                                      err_msg=f"{arch}/{rid}")
