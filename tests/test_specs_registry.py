"""Spec-first API: BackendSpec round-trip, registry dispatch equivalence
vs the legacy mode= paths, materialization caching, policy JSON."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import (ApproxPolicy, BackendSpec, Datapath,
                          MatmulBackend, available_datapaths, backend_matmul,
                          clear_materialize_cache, get_datapath, materialize,
                          materialize_cache_stats, register_datapath, spec_of)
from repro.core.families import truncated_multiplier
from repro.core.library import ApproxLibrary
from repro.core.seeds import array_multiplier

RNG = np.random.default_rng(0)


@pytest.fixture(scope="module")
def lib():
    """Tiny hand-built library: exact + trunc-2 + trunc-4 multipliers."""
    lib = ApproxLibrary()
    exact = array_multiplier(8)
    lib.add_netlist(exact, "multiplier", 8, "exact", exact,
                    name="mul8u_exact")
    for k in (2, 4):
        lib.add_netlist(truncated_multiplier(8, k), "multiplier", 8,
                        "truncation", exact)
    return lib


@pytest.fixture()
def xw():
    x = jnp.asarray(RNG.normal(size=(9, 40)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(40, 16)), jnp.float32)
    return x, w


# ----------------------------------------------------------------------
# BackendSpec: value semantics + serialization
# ----------------------------------------------------------------------
def test_spec_value_hashable():
    a = BackendSpec(mode="lut", multiplier="mul8u_trunc4", rank=3)
    b = BackendSpec(mode="lut", multiplier="mul8u_trunc4", rank=3)
    assert a == b and hash(a) == hash(b)
    assert a != a.with_(rank=4)
    assert len({a, b, a.with_(mode="lowrank")}) == 2


def test_spec_json_roundtrip():
    for spec in (BackendSpec(), BackendSpec.golden(),
                 BackendSpec(mode="lut", multiplier="mul8u_trunc2",
                             block_m=128, ste=False),
                 BackendSpec(mode="lowrank", rank=5, variant="pallas")):
        back = BackendSpec.from_json(spec.to_json())
        assert back == spec and hash(back) == hash(spec)


def test_spec_rejects_unknown_fields_and_variants():
    with pytest.raises(ValueError):
        BackendSpec.from_dict({"mode": "lut", "nope": 1})
    with pytest.raises(ValueError):
        BackendSpec(variant="cuda")


# ----------------------------------------------------------------------
# Registry: dispatch equivalence vs the legacy mode= paths
# ----------------------------------------------------------------------
def test_builtin_datapaths_registered():
    for name in ("int8", "lut", "lowrank"):
        assert name in available_datapaths()
        assert get_datapath(name) is get_datapath(name)
    with pytest.raises(KeyError):
        get_datapath("booth")   # not (yet) registered


@pytest.mark.parametrize("mode", ["lut", "lowrank"])
@pytest.mark.parametrize("variant", ["ref", "pallas"])
def test_registry_matches_legacy_paths(lib, xw, mode, variant):
    x, w = xw
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = MatmulBackend.from_library(
            "mul8u_trunc4", mode=mode, library=lib,
            use_pallas=(variant == "pallas"))
    y_old = backend_matmul(x, w, legacy)
    spec = BackendSpec(mode=mode, multiplier="mul8u_trunc4",
                       variant=variant)
    y_new = backend_matmul(x, w, spec.materialize(lib))
    np.testing.assert_allclose(np.asarray(y_new), np.asarray(y_old),
                               rtol=0, atol=0)


def test_int8_spec_matches_legacy(xw):
    x, w = xw
    y_old = backend_matmul(x, w, MatmulBackend(mode="int8"))
    y_new = backend_matmul(x, w, BackendSpec.golden())
    np.testing.assert_allclose(np.asarray(y_new), np.asarray(y_old),
                               rtol=0, atol=0)


def test_register_custom_datapath_without_touching_backend(xw):
    """New datapaths plug in through the registry alone."""
    @register_datapath("allzero")
    class AllZero(Datapath):
        needs_library = False

        def forward_q(self, qa, qw, consts):
            return jnp.zeros((qa.shape[0], qw.shape[1]), jnp.float32)

    x, w = xw
    y = backend_matmul(x, w, BackendSpec(mode="allzero"))
    assert y.shape == (x.shape[0], w.shape[1])
    assert np.isfinite(np.asarray(y)).all()


# ----------------------------------------------------------------------
# Materialization cache
# ----------------------------------------------------------------------
def test_materialize_cached_one_object_per_spec(lib):
    clear_materialize_cache()
    spec = BackendSpec(mode="lowrank", multiplier="mul8u_trunc4")
    a = materialize(spec, lib)
    b = materialize(spec, lib)
    assert a is b
    stats = materialize_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 1
    # a distinct spec packs separately
    c = materialize(spec.with_(rank=1), lib)
    assert c is not a and c.rank == 1
    assert materialize_cache_stats()["misses"] == 2


def test_materialized_backend_exposes_effective_rank(lib):
    mb = BackendSpec(mode="lowrank", multiplier="mul8u_exact",
                     rank=None).materialize(lib)
    assert mb.rank == mb.consts["u"].shape[0] >= 1
    assert mb.multiplier == "mul8u_exact" and mb.mode == "lowrank"


def test_prepare_weight_accepts_spec_backends(lib, xw):
    from repro.approx.backend import prepare_weight
    x, w = xw
    mb = BackendSpec(mode="lowrank", multiplier="mul8u_exact",
                     rank=2).materialize(lib)
    y_ref = backend_matmul(x, w, mb)
    y_prep = backend_matmul(x, prepare_weight(w, mb), mb)
    scale = float(jnp.abs(y_ref).max())
    assert float(jnp.abs(y_prep - y_ref).max()) < 0.02 * scale + 0.05


# ----------------------------------------------------------------------
# Policy serialization
# ----------------------------------------------------------------------
def test_policy_json_roundtrip(lib):
    pol = ApproxPolicy(
        default=BackendSpec.golden(),
        overrides=[("s0_*", BackendSpec(mode="lut",
                                        multiplier="mul8u_trunc2")),
                   ("head", BackendSpec.exact("f32"))])
    back = ApproxPolicy.from_json(pol.to_json())
    assert back.cache_key() == pol.cache_key()
    assert spec_of(back.backend_for("s0_conv1")).multiplier == "mul8u_trunc2"
    assert spec_of(back.backend_for("head")).mode == "f32"
    assert spec_of(back.backend_for("other")).mode == "int8"


def test_policy_json_covers_legacy_backends():
    pol = ApproxPolicy(default=MatmulBackend(mode="int8"))
    back = ApproxPolicy.from_json(pol.to_json())
    assert spec_of(back.default) == spec_of(pol.default)


def test_policy_materialize_preserves_legacy_arrays(lib, xw):
    """Legacy backends carrying hand-attached arrays must keep them
    through materialize — not be rebuilt by multiplier name."""
    x, w = xw
    zeros = MatmulBackend(mode="lut", lut=np.zeros((256, 256), np.int32),
                          multiplier="mul8u_exact")
    pol = ApproxPolicy(default=zeros).materialize(lib)
    y = backend_matmul(x, w, pol.default)
    y_direct = backend_matmul(x, w, zeros)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_direct),
                               rtol=0, atol=0)
    # and it is genuinely the zeros LUT, not the library's exact one
    y_lib = backend_matmul(x, w, BackendSpec(
        mode="lut", multiplier="mul8u_exact").materialize(lib))
    assert float(np.abs(np.asarray(y) - np.asarray(y_lib)).max()) > 1.0


def test_canonicalization_collapses_irrelevant_fields(lib):
    """Specs differing only in fields their datapath ignores share one
    materialization (and therefore one jit trace)."""
    from repro.approx import canonicalize, materialize
    # every int8 spec is the golden datapath
    assert canonicalize(BackendSpec(mode="int8", multiplier="x",
                                    rank=9, block_m=64)) \
        == BackendSpec.golden()
    assert materialize(BackendSpec(mode="int8", rank=9)) \
        is materialize(BackendSpec.golden())
    # lut ignores rank; lowrank keeps it
    a = materialize(BackendSpec(mode="lut", multiplier="mul8u_trunc4",
                                rank=4), lib)
    b = materialize(BackendSpec(mode="lut", multiplier="mul8u_trunc4"),
                    lib)
    assert a is b
    assert canonicalize(BackendSpec(mode="lowrank", rank=4)).rank == 4


def test_to_json_warns_on_hand_attached_arrays():
    pol = ApproxPolicy(default=MatmulBackend(
        mode="lut", lut=np.zeros((256, 256), np.int32)))
    with pytest.warns(UserWarning, match="hand-attached"):
        pol.to_json()


def test_cache_key_distinguishes_hand_attached_arrays(lib):
    """A hand-attached LUT must never share a policy cache key with the
    library-built spec of the same mode/multiplier."""
    zeros = MatmulBackend(mode="lut", lut=np.zeros((256, 256), np.int32),
                          multiplier="mul8u_exact")
    spec = BackendSpec(mode="lut", multiplier="mul8u_exact")
    k_legacy = ApproxPolicy(default=zeros).cache_key()
    k_spec = ApproxPolicy(default=spec).cache_key()
    k_canon = ApproxPolicy(default=spec.materialize(lib)).cache_key()
    assert k_legacy != k_spec
    assert k_spec == k_canon   # canonical materialization == its spec
    # exact-mode legacy backends carry no arrays: spec-identified
    assert ApproxPolicy(default=MatmulBackend(mode="int8")).cache_key() \
        == ApproxPolicy(default=BackendSpec.golden()).cache_key()


def test_policy_materialize_shares_backend_objects(lib):
    clear_materialize_cache()
    spec = BackendSpec(mode="lut", multiplier="mul8u_trunc4")
    p1 = ApproxPolicy(default=spec).materialize(lib)
    p2 = ApproxPolicy(default=spec).materialize(lib)
    assert p1.default is p2.default   # same object -> same jit trace key
