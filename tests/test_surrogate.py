"""Surrogate predict stage (DESIGN.md §2.11): feature extraction,
fit/predict/calibration, the LayerComponents factory with exact
measured-cell overrides, and the explore_heterogeneous wiring."""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.dse import explore_heterogeneous
from repro.approx.layers import ApproxPolicy
from repro.approx.ranking import spearman
from repro.approx.specs import BackendSpec
from repro.approx.surrogate import (FEATURE_NAMES, STRUCTURE_SLICE,
                                    SurrogateConfig, circuit_features,
                                    feature_matrix, fit_surrogate,
                                    surrogate_components, train_subset)
from repro.approx.workload import logit_fidelity
from repro.core.library import build_default_library

LAYERS = ("lin_a", "lin_b")
COUNTS = {"lin_a": 100, "lin_b": 300}


@pytest.fixture(scope="module")
def lib():
    return build_default_library("tiny")


@pytest.fixture(scope="module")
def names(lib):
    return [e.name for e in lib.select(kind="multiplier", width=8)]


@pytest.fixture(scope="module")
def toy_workload():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w_a = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    w_b = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)

    def forward(policy, xb):
        y = policy.matmul("lin_a", xb, w_a)
        return policy.matmul("lin_b", jax.nn.relu(y), w_b)

    return logit_fidelity(forward, [x], layer_counts=dict(COUNTS))


def _synthetic_rows(lib, names):
    """Duck-typed sweep rows (the DesignPoint-corpus contract: only
    .layer/.multiplier/.accuracy are read) whose drop is a smooth
    monotone function of the error features — learnable by
    construction."""
    rows = []
    for n in names:
        e = lib.entry(n)
        d = 2.0 * np.log1p(e.errors.mae) + 0.5 * np.log1p(e.errors.wce)
        for scale, layer in zip((1.0, 0.4), LAYERS):
            rows.append(SimpleNamespace(layer=layer, multiplier=n,
                                        accuracy=1.0 - scale * d))
    rows.append(SimpleNamespace(layer="all", multiplier=names[0],
                                accuracy=0.0))        # must be ignored
    return rows


# ----------------------------------------------------------------------
# Features
# ----------------------------------------------------------------------
def test_feature_vector_shape_and_exact_entry(lib):
    v = circuit_features(lib.entry("mul8u_exact"))
    assert v.shape == (len(FEATURE_NAMES),)
    fx = dict(zip(FEATURE_NAMES, v))
    # the exact multiplier has zero error and unit relative power
    for m in ("er", "mae", "mse", "mre", "wce", "wcre"):
        assert fx[f"log1p_{m}"] == 0.0
    assert fx["rel_power"] == pytest.approx(1.0)
    assert fx["src_exact"] == 1.0 and fx["src_bam"] == 0.0
    assert fx["width_over_8"] == 1.0
    # gate fractions sum to 1 over active nodes
    gate_sum = sum(fx[f"gate_frac_{f}"] for f in range(10))
    assert gate_sum == pytest.approx(1.0)


def test_feature_matrix_discriminates(lib, names):
    x = feature_matrix([lib.entry(n) for n in names[:10]])
    assert x.shape == (10, len(FEATURE_NAMES))
    # no two distinct circuits share a feature vector
    assert len({tuple(row) for row in x}) == 10
    # the structure slice excludes the error/cost report columns
    assert FEATURE_NAMES[STRUCTURE_SLICE][0] == "width_over_8"
    assert "log1p_mae" not in FEATURE_NAMES[STRUCTURE_SLICE]


def test_netlist_structure_features(lib):
    nl = lib.entry("mul8u_exact").netlist
    hist = nl.gate_histogram()
    assert hist.shape == (10,) and hist.sum() == nl.n_active()
    assert 0 < nl.logic_depth() <= nl.n_active()
    # truncated multiplier: strictly smaller circuit than exact
    nl_t = lib.entry("mul8u_trunc4").netlist
    assert nl_t.gate_histogram().sum() < hist.sum()


def test_error_report_as_vector(lib):
    e = lib.entry("mul8u_trunc4").errors
    v = e.as_vector()
    assert v.shape == (6,)
    assert v[0] == e.er and v[4] == e.wce


# ----------------------------------------------------------------------
# Fit / predict / calibrate
# ----------------------------------------------------------------------
def test_fit_surrogate_learns_monotone_target(lib, names):
    rows = _synthetic_rows(lib, names)
    pred = fit_surrogate(rows, lib, baseline=1.0, direction="max",
                         config=SurrogateConfig(epochs=800))
    assert pred.layers == LAYERS
    assert pred.val_names
    assert not set(pred.val_names) & set(pred.train_names)
    assert len(pred.train_names) + len(pred.val_names) == len(names)
    d = pred.predict_drop(names, lib)
    assert d.shape == (2, len(names)) and (d >= 0).all()
    true = np.array([2.0 * np.log1p(lib.entry(n).errors.mae)
                     + 0.5 * np.log1p(lib.entry(n).errors.wce)
                     for n in names])
    # a smooth monotone target must be rank-recovered on both layers
    assert spearman(d[0], true) > 0.9
    assert spearman(d[1], 0.4 * true) > 0.9
    # quality re-bases drops in the primary's direction
    q = pred.predict_quality(names, lib)
    np.testing.assert_allclose(q, 1.0 - d)
    assert pred.calibration >= 0.0
    diag = pred.summary()
    assert diag["holdout"] == "val" and diag["n_val"] == len(pred.val_names)
    assert set(diag["val_spearman"]) == set(LAYERS)


def test_fit_surrogate_min_direction_and_cost_head(lib, names):
    rows = []
    for n in names:
        d = np.log1p(lib.entry(n).errors.mae)
        rows.append(SimpleNamespace(layer="l0", multiplier=n,
                                    accuracy=0.1 + d))   # MAE rises
    pred = fit_surrogate(rows, lib, baseline=0.1, direction="min",
                         config=SurrogateConfig(epochs=400))
    q = pred.predict_quality(names, lib)
    assert (q >= 0.1).all()          # min primary only degrades upward
    # learned cost head ranks relative power from structure alone
    rp_true = np.array([lib.entry(n).rel_power for n in names])
    rp_pred = pred.predict_rel_power(names, lib)
    assert spearman(rp_pred, rp_true) > 0.8
    assert np.isfinite(pred.summary()["power_spearman"])


def test_fit_surrogate_needs_enough_circuits(lib):
    rows = _synthetic_rows(lib, ["mul8u_exact", "mul8u_trunc4"])
    with pytest.raises(ValueError, match=">= 3 circuits"):
        fit_surrogate(rows, lib, baseline=1.0)


def test_train_subset_deterministic_power_spread(lib, names):
    sub = train_subset(names, lib, 0.25)
    assert sub == train_subset(names, lib, 0.25)
    assert len(sub) == int(np.ceil(0.25 * len(names)))
    rp = [lib.entry(n).rel_power for n in names]
    # endpoints of the power axis are always measured
    assert min(names, key=lambda n: (lib.entry(n).rel_power, n)) in sub
    assert max(names, key=lambda n: (lib.entry(n).rel_power, n)) in sub
    # floor of 6 (or everything, below that)
    assert len(train_subset(names[:4], lib, 0.1)) == 4
    assert len(train_subset(names[:20], lib, 0.05)) == 6


# ----------------------------------------------------------------------
# Components factory + DSE wiring
# ----------------------------------------------------------------------
def test_surrogate_components_exact_cells_override(lib, names, toy_workload):
    sub = names[:16]
    golden = ApproxPolicy(default=BackendSpec.golden().materialize())
    baseline = toy_workload.measure(golden)["logit_mae"]
    comp, pred, rows = surrogate_components(
        toy_workload, COUNTS, sub, lib, baseline=baseline,
        direction="min", train_fraction=0.4)
    assert comp.layers == LAYERS and comp.multipliers == tuple(sub)
    assert comp.quality.shape == (2, len(sub))
    # every measured row's cell is the EXACT value, not a prediction
    li = {l: j for j, l in enumerate(comp.layers)}
    mi = {m: i for i, m in enumerate(comp.multipliers)}
    for r in rows:
        assert comp.quality[li[r.layer], mi[r.multiplier]] == r.accuracy
    # power is the library's exact accounting for every candidate
    np.testing.assert_allclose(
        comp.rel_power, [lib.entry(n).rel_power for n in sub])
    measured = {r.multiplier for r in rows}
    assert measured == set(pred.train_names) | set(pred.val_names)
    assert len(measured) < len(sub)


def test_explore_heterogeneous_surrogate_path(lib, names, toy_workload):
    res = explore_heterogeneous(
        toy_workload, COUNTS, lib, multipliers=names[:16],
        quality_bound=10.0, top_k=4,
        predictor="surrogate", train_fraction=0.4)
    s = res.surrogate
    assert s is not None and s["train_fraction"] == 0.4
    assert s["beam_bound"] == pytest.approx(10.0 + s["calibration"])
    # stage 1 measured only the training subset
    assert len(res.per_layer) == len(LAYERS) * (s["n_train"] + s["n_val"])
    assert len(res.per_layer) < len(LAYERS) * 16
    # stage 2 is exact: points carry real measurements and assignments
    assert res.heterogeneous
    for p in res.heterogeneous:
        assert p.layer == "hetero" and set(dict(p.assignment)) == set(COUNTS)
    # the surrogate record round-trips through JSON
    d = res.to_json_dict()
    assert "surrogate" in d
    from repro.approx.dse import ExploreResult
    rt = ExploreResult.from_json_dict(d)
    assert rt.to_json_dict() == d


def test_exact_path_has_no_surrogate_record(lib, toy_workload):
    res = explore_heterogeneous(
        toy_workload, COUNTS, lib,
        multipliers=["mul8u_exact", "mul8u_trunc4", "mul8u_trunc2"],
        quality_bound=30.0, top_k=4)
    assert res.surrogate is None
    assert "surrogate" not in res.to_json_dict()


def test_unknown_predictor_raises(lib, toy_workload):
    with pytest.raises(ValueError, match="predictor"):
        explore_heterogeneous(toy_workload, COUNTS, lib,
                              multipliers=["mul8u_exact"],
                              predictor="oracle")
