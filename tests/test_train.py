"""Training substrate: optimizer, checkpointing (atomic + reshard),
NaN-guard auto-restore, microbatch accumulation, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (compress_with_feedback, compressed_psum,
                                     init_residual, quantize_leaf,
                                     dequantize_leaf)
from repro.train.loop import (StragglerMonitor, Trainer, TrainLoopConfig,
                              make_train_step)
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state, lr_at)


def _quadratic_loss(params, batch):
    return jnp.sum((params["w"] - batch["target"]) ** 2)


def test_adamw_converges_quadratic():
    params = {"w": jnp.ones((8,)) * 5.0}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(lr=0.2, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
    batch = {"target": jnp.zeros((8,))}
    step = jax.jit(make_train_step(_quadratic_loss, cfg))
    for _ in range(150):
        params, opt, m = step(params, opt, batch)
    assert float(m["loss"]) < 1e-2


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr_at(cfg, jnp.asarray(100))) <= 0.11
    assert float(lr_at(cfg, jnp.asarray(5))) == pytest.approx(0.5)


def test_microbatch_accumulation_matches_full_batch():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)

    def loss(params, batch):
        return jnp.mean((batch["x"] @ params["w"]) ** 2)

    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
    full = make_train_step(loss, cfg, microbatches=1)
    micro = make_train_step(loss, cfg, microbatches=4)
    p1, _, m1 = full({"w": w}, init_opt_state({"w": w}), {"x": x})
    p2, _, m2 = micro({"w": w}, init_opt_state({"w": w}),
                      {"x": x.reshape(4, 2, 4)})
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5, atol=1e-6)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
             "nested": {"b": jnp.ones((4,))}}
    for s in (1, 2, 3):
        mgr.save(s, state, metadata={"step": s})
    assert mgr.latest_step() == 3
    # GC keeps only 2
    steps = [d for d in os.listdir(tmp_path) if d.startswith("step-")]
    assert len(steps) == 2
    restored, meta = mgr.restore(state)
    assert meta["step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.ones((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.ones((5,))})


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, {"a": jnp.ones((2,))})
    assert not any(d.startswith("tmp-") for d in os.listdir(tmp_path))


def test_nan_guard_restores(tmp_path):
    """Step 5 produces a poisoned batch -> trainer must restore and keep
    the params finite and training running."""
    calls = {"n": 0}

    def loss(params, batch):
        return jnp.sum((params["w"] * batch["x"]) ** 2)

    params = {"w": jnp.ones((4,))}
    loop_cfg = TrainLoopConfig(total_steps=12, ckpt_every=2,
                               ckpt_dir=str(tmp_path), log_every=100,
                               nan_skip_window=2)
    trainer = Trainer(loss, params, OptimizerConfig(lr=0.01,
                                                    warmup_steps=0),
                      loop_cfg, donate=False)

    def batches():
        step = 0
        while True:
            x = np.ones(4, np.float32)
            if step == 5:
                x = x * np.nan
            yield {"x": jnp.asarray(x)}
            step += 1

    hist = trainer.run(batches(), log=lambda s: None)
    assert trainer.nan_events == [5]
    assert np.isfinite(np.asarray(trainer.params["w"])).all()
    assert trainer.step >= 12


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0)
    for i in range(10):
        assert not mon.record(i, 0.1)
    assert mon.record(10, 1.0)
    assert mon.flagged == [(10, 1.0)]


# ---------------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31), st.floats(0.01, 1000))
def test_quantize_leaf_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(scale * rng.standard_normal(64), jnp.float32)
    q, s = quantize_leaf(g)
    err = jnp.abs(dequantize_leaf(q, s) - g)
    assert float(err.max()) <= float(s) * 0.5001


def test_error_feedback_accumulates():
    g = {"w": jnp.asarray([1e-4, 1.0, -1.0], jnp.float32)}
    res = init_residual(g)
    total = jnp.zeros((3,))
    for _ in range(100):
        deq, res = compress_with_feedback(g, res)
        total = total + deq["w"]
    # with feedback, the tiny 1e-4 component must not be lost over time
    np.testing.assert_allclose(np.asarray(total / 100),
                               np.asarray(g["w"]), rtol=0.05, atol=2e-5)


def test_compressed_psum_single_device():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray([0.5, -2.0, 3.0], jnp.float32)}
    f = shard_map(lambda t: compressed_psum(t, "pod"), mesh=mesh,
                  in_specs=(P(),), out_specs=P())
    out = f(g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               rtol=0.02, atol=0.02)
