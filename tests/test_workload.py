"""Workload layer (DESIGN.md §2.7): as_workload normalization,
classification parity with the legacy BankableEval path, and the LM
adapters (fidelity + perplexity) on a tiny decoder config — including
the objective-first ``explore(workload=..., objectives=...)`` endpoint
returning a 3-axis front."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.dse import explore
from repro.approx.layers import ApproxPolicy, EXACT_POLICY
from repro.approx.objectives import get_objective, value_of
from repro.approx.resilience import BankableEval, all_layers_sweep
from repro.approx.specs import BackendSpec
from repro.approx.workload import (Workload, as_workload, classification,
                                   lm_fidelity, lm_layer_mult_counts,
                                   lm_perplexity, logit_fidelity)
from repro.core.families import truncated_multiplier
from repro.core.library import ApproxLibrary
from repro.core.seeds import array_multiplier
from repro.models.common import LMConfig

LAYER_COUNTS = {"layer_a": 100, "layer_b": 300}
MULTS = ["mul8u_exact", "mul8u_trunc6", "mul8u_trunc3"]


@pytest.fixture(scope="module")
def lib():
    lib = ApproxLibrary()
    exact = array_multiplier(8)
    lib.add_netlist(exact, "multiplier", 8, "exact", exact,
                    name="mul8u_exact")
    for k in (2, 5):
        lib.add_netlist(truncated_multiplier(8, k), "multiplier", 8,
                        "truncation", exact)
    return lib


@pytest.fixture(scope="module")
def tiny_cfg():
    return LMConfig(name="tiny-dense", family="dense", n_layers=2,
                    d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                    vocab=128, head_dim=16, dtype=jnp.float32,
                    remat=False, loss_chunk=16)


# ----------------------------------------------------------------------
# Normalization shims
# ----------------------------------------------------------------------
def test_as_workload_plain_callable():
    wl = as_workload(lambda policy: 0.5)
    assert isinstance(wl, Workload)
    assert wl.metrics == ("accuracy",) and wl.primary == "accuracy"
    assert wl.traceable is None and wl.traceable_metrics is None
    assert wl(EXACT_POLICY) == 0.5
    assert wl.measure(EXACT_POLICY) == {"accuracy": 0.5}


def test_as_workload_bankable_eval_preserves_traceable():
    be = BankableEval(fn=lambda p: 0.25,
                      traceable=lambda p: jnp.float32(0.25))
    wl = as_workload(be)
    assert wl.metrics == ("accuracy",)
    assert float(wl.traceable(EXACT_POLICY)) == 0.25
    assert wl.traceable_metrics(EXACT_POLICY)["accuracy"] == 0.25


def test_as_workload_is_identity_on_workloads():
    wl = Workload(name="w", fn=lambda p: {"m": 1.0}, metrics=("m",))
    assert as_workload(wl) is wl


def test_workload_primary_validation_and_registration():
    with pytest.raises(ValueError):
        Workload(name="w", fn=lambda p: {}, metrics=())
    with pytest.raises(ValueError):
        Workload(name="w", fn=lambda p: {"m": 1.0}, metrics=("m",),
                 primary="other")
    Workload(name="w", fn=lambda p: {"wl_test_axis": 1.0},
             metrics=("wl_test_axis",),
             directions={"wl_test_axis": "min"})
    assert get_objective("wl_test_axis").direction == "min"


def test_workload_cached_hits_policy_cache():
    calls = [0]

    def fn(policy):
        calls[0] += 1
        return {"accuracy": 0.5}

    cache: dict = {}
    wl = Workload(name="w", fn=fn, metrics=("accuracy",)).cached(cache)
    policy = ApproxPolicy(default=BackendSpec.golden())
    assert wl.measure(policy) == {"accuracy": 0.5}
    assert wl.measure(policy) == {"accuracy": 0.5}
    assert calls[0] == 1 and len(cache) == 1


# ----------------------------------------------------------------------
# Sweep parity: Workload vs legacy scalar eval
# ----------------------------------------------------------------------
def test_sweep_rows_carry_metric_dicts_and_costs(lib):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    ref = np.asarray(x) @ np.asarray(w)

    def eval_fn(policy):
        err = float(np.abs(np.asarray(
            policy.matmul("layer_a", x, w)) - ref).mean())
        return 1.0 / (1.0 + err)

    rows_legacy = all_layers_sweep(eval_fn, LAYER_COUNTS, MULTS, lib,
                                   mode="lut")
    wl = Workload(name="toy",
                  fn=lambda p: {"accuracy": eval_fn(p)},
                  metrics=("accuracy",))
    rows_wl = all_layers_sweep(wl, LAYER_COUNTS, MULTS, lib, mode="lut")
    for a, b in zip(rows_legacy, rows_wl):
        assert a.accuracy == b.accuracy == b.metrics["accuracy"]
        assert a.metrics == {"accuracy": a.accuracy}
        # cost axes threaded onto every row, exact circuit at 1.0
        assert set(a.costs) == {"area", "delay"}
    exact_row = next(r for r in rows_wl if r.multiplier == "mul8u_exact")
    assert exact_row.costs["area"] == pytest.approx(1.0)
    assert exact_row.costs["delay"] == pytest.approx(1.0)


def test_cost_axes_map_synthesizes_missing_width_reference(lib):
    """A width with no mul{W}u_exact library entry (composed 16-bit in
    a tiny library) must still land on the RELATIVE scale — reference
    synthesized from an exact array multiplier, never raw ps/um2 mixed
    with ~1.0 ratios."""
    from repro.approx.power import cost_axes_map
    wide = lib.add_composed("mul8u_exact", 16, "loa4").name
    cmap = cost_axes_map(lib, ["mul8u_exact", "mul8u_trunc6", wide])
    assert cmap["mul8u_exact"]["delay"] == pytest.approx(1.0)
    # relative, same order of magnitude as the 8-bit ratios — a raw
    # 45nm delay would be hundreds of picoseconds
    for axis in ("area", "delay"):
        assert 0.0 < cmap[wide][axis] < 20.0


# ----------------------------------------------------------------------
# Shipped adapters
# ----------------------------------------------------------------------
def test_classification_workload_matches_direct_eval():
    from repro.models import resnet
    cfg = resnet.resnet_config(8)
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    wl = classification(cfg, params, eval_n=32, batch=32)
    assert wl.metrics == ("accuracy",) and wl.primary == "accuracy"
    assert wl.layer_counts == resnet.layer_mult_counts(cfg)
    acc = wl.measure(EXACT_POLICY)["accuracy"]
    assert 0.0 <= acc <= 1.0
    # scalar shim + traceable projection agree
    assert wl(EXACT_POLICY) == acc
    assert float(jax.jit(
        lambda: wl.traceable(EXACT_POLICY))()) == acc


def test_logit_fidelity_exact_policy_is_perfect():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)
    batches = [jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
               for _ in range(2)]

    def forward(policy, x):
        return policy.matmul("proj", x, w)

    wl = logit_fidelity(forward, batches)
    m = wl.measure(EXACT_POLICY)
    # the reference is computed eagerly, the measurement under jit —
    # fusion differences leave float-ulp residue, not exact zero
    assert m["logit_mae"] < 1e-5
    assert m["top1_agreement"] == 1.0
    assert wl.primary == "logit_mae"
    assert get_objective("logit_mae").direction == "min"
    assert get_objective("top1_agreement").direction == "max"


def test_lm_fidelity_on_tiny_decoder(tiny_cfg, lib):
    wl = lm_fidelity(tiny_cfg, batch=2, seq_len=8, n_batches=1)
    assert wl.metrics == ("logit_mae", "top1_agreement")
    assert set(wl.layer_counts) == {"attn.wq", "attn.wk", "attn.wv",
                                    "attn.wo", "ffn.wi", "ffn.wg",
                                    "ffn.wo"}
    exact = wl.measure(EXACT_POLICY)
    assert exact["logit_mae"] < 1e-5 and exact["top1_agreement"] == 1.0
    # an aggressive truncation must hurt fidelity measurably
    rough = wl.measure(ApproxPolicy(default=BackendSpec.from_library(
        "mul8u_trunc3", mode="lut")).materialize(lib))
    golden = wl.measure(ApproxPolicy(
        default=BackendSpec.golden()).materialize(lib))
    assert rough["logit_mae"] > golden["logit_mae"] >= 0.0


def test_lm_perplexity_on_tiny_decoder(tiny_cfg):
    wl = lm_perplexity(tiny_cfg, batch=2, seq_len=8, n_batches=1)
    m = wl.measure(EXACT_POLICY)
    assert m["perplexity"] == pytest.approx(float(np.exp(m["loss"])),
                                            rel=1e-6)
    assert m["perplexity"] > 1.0
    assert wl.primary_direction == "min"


def test_lm_adapter_supports_encdec():
    # §2.12: the adapters feed registry.input_extras (frame embeddings)
    # so whisper-family configs run through lm_fidelity unchanged.
    cfg = LMConfig(name="w", family="encdec", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab=128,
                   head_dim=16, n_enc_layers=2, enc_frames=8,
                   use_rope=False, act="gelu", dtype=jnp.float32,
                   remat=False, loss_chunk=16)
    wl = lm_fidelity(cfg, batch=1, seq_len=8, n_batches=1)
    assert {"enc.attn.wq", "dec.attn.wq", "xattn.wk",
            "enc.ffn.wi"} <= set(wl.layer_counts)
    m = wl.measure(EXACT_POLICY)
    # reference logits are computed eagerly, the measurement jitted —
    # f32 contraction-order noise only
    assert m["logit_mae"] < 1e-6 and m["top1_agreement"] == 1.0


def test_unified_layer_mult_counts_covers_resnet_head():
    from repro.approx.workload import layer_mult_counts
    from repro.models.resnet import ResNetConfig, layer_mult_counts as shim
    cfg = ResNetConfig()
    unified = layer_mult_counts(cfg)
    legacy = shim(cfg)
    assert unified["head"] == cfg.widths[-1] * cfg.n_classes
    assert {k: v for k, v in unified.items() if k != "head"} == legacy


def test_lm_layer_mult_counts_scale_with_layers(tiny_cfg):
    c1 = lm_layer_mult_counts(tiny_cfg, batch=2, seq_len=8)
    import dataclasses
    c2 = lm_layer_mult_counts(
        dataclasses.replace(tiny_cfg, n_layers=4), batch=2, seq_len=8)
    assert all(c2[k] == 2 * c1[k] for k in c1)


# ----------------------------------------------------------------------
# Objective-first explore() (the acceptance-criteria endpoint)
# ----------------------------------------------------------------------
def test_explore_workload_objectives_three_axis_front(tiny_cfg, lib):
    wl = lm_fidelity(tiny_cfg, batch=2, seq_len=8, n_batches=1)
    result = explore(workload=wl, library=lib, multipliers=MULTS,
                     mode="lut", per_layer=False,
                     objectives=("logit_mae", "power", "delay"))
    assert result.primary == "logit_mae"
    assert result.objectives == ("logit_mae", "power", "delay")
    assert result.baseline_metrics.keys() == {"logit_mae",
                                              "top1_agreement"}
    assert len(result.all_layers) == len(MULTS)
    for p in result.all_layers:
        assert set(p.metrics) == {"logit_mae", "top1_agreement"}
        assert set(p.costs) == {"area", "delay"}
    front = result.pareto()
    assert 0 < len(front) <= len(MULTS)
    # the front is non-dominated over all three axes
    for p in front:
        for q in result.all_layers:
            assert not (
                value_of(q, "logit_mae") <= value_of(p, "logit_mae")
                and value_of(q, "power") <= value_of(p, "power")
                and value_of(q, "delay") <= value_of(p, "delay")
                and (value_of(q, "logit_mae") < value_of(p, "logit_mae")
                     or value_of(q, "power") < value_of(p, "power")
                     or value_of(q, "delay") < value_of(p, "delay")))
    # exact tile has the best fidelity, so it must be on the front
    assert any(p.multiplier == "mul8u_exact" for p in front)


def test_explore_workload_layer_counts_defaulted(lib):
    calls = [0]

    def fn(policy):
        calls[0] += 1
        return {"accuracy": 0.5}

    wl = Workload(name="w", fn=fn, metrics=("accuracy",),
                  layer_counts={"layer_a": 10})
    result = explore(workload=wl, library=lib, multipliers=MULTS,
                     mode="lut")
    assert len(result.per_layer) == len(MULTS)
    assert result.baseline_metrics == {"accuracy": 0.5}


def test_explore_requires_some_eval():
    with pytest.raises(TypeError):
        explore(layer_counts={"a": 1})
